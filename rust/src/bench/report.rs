//! `BENCH_serving.json` serialization: the per-method report row, its JSON
//! encoders, the schema-versioned append-only trajectory writer, and the
//! human-readable serving table.
//!
//! Split out of `bench::loadgen` so the traffic-driving machinery and the
//! recording format live apart: this module owns *what a trajectory row
//! looks like* (key names, key order, optional-column presence rules,
//! NaN→null mapping), and the load generator / scenario runner own how the
//! numbers are produced.  The schema is frozen by the byte-identical
//! regression test at the bottom — a row serializes to exactly the same
//! bytes it did before the split, so every existing trajectory reader and
//! CI guard keeps parsing unchanged.
//!
//! `loadgen` re-exports everything here under its old paths
//! (`loadgen::MethodReport`, `loadgen::append_trajectory`, ...), so callers
//! keep one import surface.

use std::path::Path;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};
use crate::util::stats::{Histogram, Summary};

use super::loadgen::{ArrivalMode, LoadGenConfig, PolicyFlags};
use super::Table;

/// Schema version stamped into `BENCH_serving.json`; bump on any breaking
/// change to the entry layout (readers must check it).
pub const TRAJECTORY_SCHEMA: f64 = 1.0;

/// Aggregated outcome of one load run against one server configuration —
/// one row of the `BENCH_serving.json` per-method table.
#[derive(Debug, Clone)]
pub struct MethodReport {
    /// Method label (`spa`, `vanilla`, ...).
    pub method: String,
    /// Requests completed inside the measured window.
    pub requests: usize,
    /// Of those, how many came back as `{"error": ...}`.
    pub errors: usize,
    /// Open-loop arrivals inside the measured window dropped at the
    /// `max_inflight` cap (overload; warmup-window drops are not counted).
    pub dropped: usize,
    /// Length of the measured window actually observed (s).
    pub measured_s: f64,
    /// Configured offered load (open loop) or NaN (closed loop).
    pub offered_qps: f64,
    /// Completions per second inside the measured window.
    pub achieved_qps: f64,
    /// Decoded tokens per second inside the measured window.
    pub tps: f64,
    /// TTFT percentiles over measured requests (server-reported).
    pub ttft: Option<Summary>,
    /// End-to-end latency percentiles (server-reported, includes queue).
    pub latency: Option<Summary>,
    /// Client-side wall-time percentiles (latency + wire).
    pub wall: Option<Summary>,
    /// Mean concurrently in-flight requests over the measured window
    /// (Little's law: Σ wall time / window).  The pipelined mode's
    /// headline number — >1 on a single connection means head-of-line
    /// blocking is gone; ≈`clients` in the closed loop.
    pub mean_inflight: f64,
    /// Mean batcher queue wait *inside the measured window*, reconstructed
    /// from the scraped mean+count pairs at the warmup boundary and end of
    /// run (a lifetime mean would smear warmup cold-start waits into every
    /// trajectory entry).
    pub queue_wait_ms_mean: f64,
    /// Cache refreshes inside the measured window (scraped, differenced).
    pub refreshes: f64,
    /// Engine steps inside the measured window (scraped, differenced).
    pub steps: f64,
    /// Full-refresh steps per engine step inside the window — the
    /// per-method refresh-rate column of the trajectory (0 when no steps
    /// were observed).
    pub refresh_rate: f64,
    /// Dirty rows healed by targeted partial servicing inside the window
    /// (scraped, differenced) — admissions that did not cost a refresh.
    pub partial_refreshes: f64,
    /// Rows whose cache validity was dropped on admission inside the
    /// window (scraped, differenced; includes the blanket-invalidate
    /// blast radius for policies without partial support).
    pub rows_invalidated: f64,
    /// Staggered per-row scheduled refreshes begun inside the window
    /// (scraped, differenced) — interval maintenance paid row-by-row
    /// instead of as group-global refresh steps.
    pub scheduled_row_refreshes: f64,
    /// Online ρ-schedule refits inside the window (scraped, differenced;
    /// 0 with `--adaptive off`).
    pub schedule_refits: f64,
    /// Budget-tier switches inside the window (scraped, differenced) —
    /// monotone evidence the controller acted, even when the end-of-run
    /// `budget_tier` gauge has moved back to where it started.
    pub tier_switches: f64,
    /// Budget tier at the end of the run (gauge — the highest tier any
    /// worker was running at; 0 with `--adaptive off`).
    pub budget_tier: f64,
    /// The adaptive budget controller was attached for **this method's**
    /// run.  Per-method because the stub lineup can force it per method
    /// name (`spa-adaptive`/`spa-fixed`) and an engine lineup applies the
    /// `--adaptive` gate only to spa-kind methods — the config block's
    /// flag alone would misdescribe the other rows.
    pub adaptive: bool,
    /// Per-step cost-ledger phases inside the measured window (μs;
    /// `spa_step_ledger_us{phase=...}`, scraped + differenced).
    pub upload_us: f64,
    /// Device execution time inside the window (μs).
    pub execute_us: f64,
    /// Device→host readback time inside the window (μs).
    pub collect_us: f64,
    /// Host sampling/commit time inside the window (μs).
    pub sample_us: f64,
    /// Frame-serialization time inside the window (μs; per-server).
    pub serialize_us: f64,
    /// Whole-step wall time inside the window (μs).
    pub step_wall_us: f64,
    /// Token rows uploaded inside the window (scraped, differenced) —
    /// under delta upload, strictly fewer than steps×batch when any row
    /// stayed clean across a step.
    pub rows_uploaded: f64,
    /// Token rows the delta path kept device-resident inside the window.
    pub rows_skipped: f64,
    /// Prefix-store lookups that found a donated prefix inside the window
    /// (scraped, differenced; 0 without `--prefix-cache`).
    pub prefix_hits: f64,
    /// Prefix-store lookups that found nothing inside the window.
    pub prefix_misses: f64,
    /// Prefix-store LRU evictions under the byte cap inside the window.
    pub prefix_evictions: f64,
    /// Entries dropped by tier-swap signature purges inside the window.
    pub prefix_purges: f64,
    /// Admissions actually seeded warm from the store inside the window.
    pub warm_admissions: f64,
    /// Submissions the router steered by cache affinity (vs plain JSQ)
    /// inside the window.
    pub affinity_dispatches: f64,
    /// Pages made resident (admissions + faults) inside the window
    /// (scraped, differenced; 0 without `--page-bytes`).
    pub pages_resident: f64,
    /// Cold pages reclaimed by the pager's eviction loop inside the window.
    pub pages_evicted: f64,
    /// Page frames returned to the free pool inside the window
    /// (eviction + slot release).
    pub pages_reclaimed: f64,
    /// Scheduled refreshes deferred — rows served stale under the grace
    /// bound inside the window (scraped, differenced; 0 without `--grace`).
    pub stale_served: f64,
    /// Admissions delayed by degraded-mode token buckets inside the window.
    pub rate_limited: f64,
    /// Transitions into degraded mode inside the window.
    pub degraded_entries: f64,
    /// Transitions out of degraded mode inside the window.
    pub degraded_exits: f64,
    /// Whether any worker was still degraded at the end of the run
    /// (gauge — end-of-run value, like `budget_tier`).
    pub degraded_mode: f64,
    /// Peak drift debt any worker reached (gauge; ≤ the `--grace` bound
    /// by construction — the recorded proof stale rows stayed in bounds).
    pub drift_debt_peak: f64,
    /// The paged slot-memory path ran for this row (`--page-bytes` and/or
    /// `--grace`).  Stamped by the run front-ends, like the prefix
    /// columns — the counters alone cannot distinguish an idle paged run
    /// from an unpaged one; rows without it omit the paged columns.
    pub paged: bool,
    /// hits / (hits + misses) over the window.  `Some` only when
    /// `--prefix-cache on` ran — absent from the trajectory row otherwise,
    /// like the `scenario` tag, so warm and cold rows are distinguishable.
    pub prefix_hit_rate: Option<f64>,
    /// TTFT p50 of a warm-serving run (ms); `Some` only with
    /// `--prefix-cache on` — the warm-vs-cold trajectory column.
    pub warm_ttft_ms: Option<f64>,
    /// Per-worker completions inside the measured window (scraped,
    /// differenced) — the router's load-balance evidence.
    pub per_worker_completed: Vec<(usize, f64)>,
    /// Scenario tag (`bench::scenario` runs only) — distinguishes scenario
    /// rows from plain load-shape rows in the trajectory.
    pub scenario: Option<String>,
    /// Per-scenario SLO attainment block (`bench::scenario` runs only).
    pub slo: Option<super::scenario::SloReport>,
    /// Retained latency sample for distribution sketches (filled by
    /// `loadgen::aggregate`).
    pub(crate) latency_samples: Vec<f64>,
}

fn fmt_pct(s: &Option<Summary>) -> (String, String, String) {
    match s {
        Some(s) => {
            (format!("{:.0}", s.p50), format!("{:.0}", s.p90), format!("{:.0}", s.p99))
        }
        None => ("-".into(), "-".into(), "-".into()),
    }
}

/// Print the per-method serving table (and a latency-distribution
/// sparkline per method) in the house bench style.
pub fn print_reports(reports: &[MethodReport]) {
    let mut t = Table::new(
        "bench-serve: serving under load",
        &[
            "method", "req", "err", "drop", "qps", "tps", "inflight", "ttft p50",
            "p90", "p99", "lat p50", "p90", "p99", "refresh", "ref/step", "partial",
            "rowref", "refits", "tier",
        ],
    );
    for r in reports {
        let (tp50, tp90, tp99) = fmt_pct(&r.ttft);
        let (lp50, lp90, lp99) = fmt_pct(&r.latency);
        t.row(vec![
            r.method.clone(),
            r.requests.to_string(),
            r.errors.to_string(),
            r.dropped.to_string(),
            format!("{:.2}", r.achieved_qps),
            format!("{:.2}", r.tps),
            format!("{:.2}", r.mean_inflight),
            tp50,
            tp90,
            tp99,
            lp50,
            lp90,
            lp99,
            format!("{:.0}", r.refreshes),
            format!("{:.3}", r.refresh_rate),
            format!("{:.0}", r.partial_refreshes),
            format!("{:.0}", r.scheduled_row_refreshes),
            format!("{:.0}", r.schedule_refits),
            format!("{:.0}", r.budget_tier),
        ]);
    }
    t.print();
    for r in reports {
        if r.latency_samples.len() >= 2 {
            let hi = r.latency_samples.iter().cloned().fold(f64::MIN, f64::max);
            if hi > 0.0 {
                let mut h = Histogram::new(0.0, hi * 1.01, 32);
                for &x in &r.latency_samples {
                    h.push(x);
                }
                println!("latency ms {:>10}  0 |{}| {:.0}", r.method, h.sparkline(), hi);
            }
        }
        let shares: Vec<String> = r
            .per_worker_completed
            .iter()
            .map(|(id, n)| format!("{id}:{n:.0}"))
            .collect();
        if !shares.is_empty() {
            println!("per-worker {:>10}  {}", r.method, shares.join("  "));
        }
    }
}

/// Every float in a trajectory entry goes through [`finite_or_null`]:
/// `Json::Num(NaN)` would serialize as the bare token `NaN`, corrupting the
/// whole append-only file for every reader.  NaN reaches a report through
/// more doors than the obvious one — a `Summary` over never-committed TTFTs,
/// a scraped `spa_ttft_ms_mean NaN` on an idle server, a windowed
/// queue-wait reconstruction whose snapshots were themselves NaN.
fn summary_json(s: &Option<Summary>) -> Json {
    match s {
        None => Json::Null,
        Some(s) => Json::obj(vec![
            ("n", Json::Num(s.n as f64)),
            ("mean", finite_or_null(s.mean)),
            ("min", finite_or_null(s.min)),
            ("p50", finite_or_null(s.p50)),
            ("p90", finite_or_null(s.p90)),
            ("p99", finite_or_null(s.p99)),
            ("max", finite_or_null(s.max)),
        ]),
    }
}

/// `x` as JSON, with NaN/±Inf mapped to `null` (JSON has no spelling for
/// them; emitting the Rust debug form would corrupt the trajectory file).
pub(crate) fn finite_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// One method row of a trajectory entry.
pub fn report_json(r: &MethodReport) -> Json {
    let mut pairs = vec![
        ("method", Json::str(&r.method)),
        ("requests", Json::Num(r.requests as f64)),
        ("errors", Json::Num(r.errors as f64)),
        ("dropped", Json::Num(r.dropped as f64)),
        ("measured_s", finite_or_null(r.measured_s)),
        ("offered_qps", finite_or_null(r.offered_qps)),
        ("achieved_qps", finite_or_null(r.achieved_qps)),
        ("tps", finite_or_null(r.tps)),
        ("ttft_ms", summary_json(&r.ttft)),
        ("latency_ms", summary_json(&r.latency)),
        ("wall_ms", summary_json(&r.wall)),
        ("mean_inflight", finite_or_null(r.mean_inflight)),
        ("queue_wait_ms_mean", finite_or_null(r.queue_wait_ms_mean)),
        ("refreshes", finite_or_null(r.refreshes)),
        ("steps", finite_or_null(r.steps)),
        ("refresh_rate", finite_or_null(r.refresh_rate)),
        ("partial_refreshes", finite_or_null(r.partial_refreshes)),
        ("rows_invalidated", finite_or_null(r.rows_invalidated)),
        ("scheduled_row_refreshes", finite_or_null(r.scheduled_row_refreshes)),
        ("schedule_refits", finite_or_null(r.schedule_refits)),
        ("tier_switches", finite_or_null(r.tier_switches)),
        ("budget_tier", finite_or_null(r.budget_tier)),
        ("adaptive", Json::Bool(r.adaptive)),
        (
            "ledger",
            Json::obj(vec![
                ("upload_us", finite_or_null(r.upload_us)),
                ("execute_us", finite_or_null(r.execute_us)),
                ("collect_us", finite_or_null(r.collect_us)),
                ("sample_us", finite_or_null(r.sample_us)),
                ("serialize_us", finite_or_null(r.serialize_us)),
                ("step_wall_us", finite_or_null(r.step_wall_us)),
                ("rows_uploaded", finite_or_null(r.rows_uploaded)),
                ("rows_skipped", finite_or_null(r.rows_skipped)),
            ]),
        ),
        (
            "per_worker_completed",
            Json::Arr(
                r.per_worker_completed
                    .iter()
                    .map(|(id, n)| {
                        Json::obj(vec![
                            ("worker", Json::Num(*id as f64)),
                            ("completed", finite_or_null(*n)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    // Warm-serving rows (`--prefix-cache on`) carry the prefix columns;
    // cold rows omit them entirely — readers tell warm from cold by key
    // presence, exactly like the scenario tag below.
    if let Some(hr) = r.prefix_hit_rate {
        pairs.push(("prefix_hit_rate", finite_or_null(hr)));
        pairs.push(("prefix_hits", finite_or_null(r.prefix_hits)));
        pairs.push(("prefix_misses", finite_or_null(r.prefix_misses)));
        pairs.push(("prefix_evictions", finite_or_null(r.prefix_evictions)));
        pairs.push(("prefix_purges", finite_or_null(r.prefix_purges)));
        pairs.push(("warm_admissions", finite_or_null(r.warm_admissions)));
        pairs.push(("affinity_dispatches", finite_or_null(r.affinity_dispatches)));
    }
    if let Some(w) = r.warm_ttft_ms {
        pairs.push(("warm_ttft_ms", finite_or_null(w)));
    }
    // Paged rows (`--page-bytes`/`--grace`) carry the slot-memory and
    // overload columns; unpaged rows omit them — key presence is the
    // discriminator, like the prefix columns above.
    if r.paged {
        pairs.push(("pages_resident", finite_or_null(r.pages_resident)));
        pairs.push(("pages_evicted", finite_or_null(r.pages_evicted)));
        pairs.push(("pages_reclaimed", finite_or_null(r.pages_reclaimed)));
        pairs.push(("stale_served", finite_or_null(r.stale_served)));
        pairs.push(("rate_limited", finite_or_null(r.rate_limited)));
        pairs.push(("degraded_entries", finite_or_null(r.degraded_entries)));
        pairs.push(("degraded_exits", finite_or_null(r.degraded_exits)));
        pairs.push(("degraded_mode", finite_or_null(r.degraded_mode)));
        pairs.push(("drift_debt_peak", finite_or_null(r.drift_debt_peak)));
    }
    // Scenario rows carry their tag + schema-versioned SLO block
    // (DESIGN.md §10); plain load-shape rows omit both keys entirely.
    if let Some(s) = &r.scenario {
        pairs.push(("scenario", Json::str(s)));
    }
    if let Some(slo) = &r.slo {
        pairs.push(("slo", super::scenario::slo_json(slo)));
    }
    Json::obj(pairs)
}

/// The `config` block of a trajectory entry — everything needed to decide
/// whether two entries are comparable, the policy gates included (two
/// runs differing only in `--partial-refresh` must be distinguishable).
pub fn config_json(
    cfg: &LoadGenConfig,
    workers: usize,
    model: &str,
    policy: PolicyFlags,
) -> Json {
    let (mode, load) = match cfg.mode {
        ArrivalMode::Open { qps } => ("open", Json::Num(qps)),
        ArrivalMode::Closed { clients } => ("closed", Json::Num(clients as f64)),
        ArrivalMode::Pipelined { depth } => ("pipelined", Json::Num(depth as f64)),
    };
    Json::obj(vec![
        ("mode", Json::str(mode)),
        ("load", load),
        ("workers", Json::Num(workers as f64)),
        ("model", Json::str(model)),
        ("partial_refresh", Json::Bool(policy.partial_refresh)),
        (
            "refresh_interval",
            match policy.refresh_interval {
                None => Json::Null,
                Some(i) => Json::Num(i as f64),
            },
        ),
        ("adaptive", Json::Bool(policy.adaptive)),
        (
            "row_refresh_per_step",
            match policy.row_refresh_per_step {
                None => Json::Null,
                Some(i) => Json::Num(i as f64),
            },
        ),
        (
            "refit_interval",
            match policy.refit_interval {
                None => Json::Null,
                Some(i) => Json::Num(i as f64),
            },
        ),
        ("prefix_cache", Json::Bool(policy.prefix_cache)),
        (
            "prefix_mem",
            match policy.prefix_mem {
                None => Json::Null,
                Some(b) => Json::Num(b as f64),
            },
        ),
        (
            "page_bytes",
            match policy.page_bytes {
                None => Json::Null,
                Some(b) => Json::Num(b as f64),
            },
        ),
        (
            "grace",
            match policy.grace {
                None => Json::Null,
                Some(g) => Json::Num(g as f64),
            },
        ),
        ("warmup_s", Json::Num(cfg.warmup.as_secs_f64())),
        ("duration_s", Json::Num(cfg.duration.as_secs_f64())),
        (
            "tasks",
            Json::Arr(cfg.tasks.iter().map(|t| Json::str(t.name())).collect()),
        ),
        (
            "gen_len",
            match cfg.gen_len {
                None => Json::Null,
                Some(d) => Json::obj(vec![
                    ("lo", Json::Num(d.lo as f64)),
                    ("hi", Json::Num(d.hi as f64)),
                ]),
            },
        ),
        ("seed", Json::Num(cfg.seed as f64)),
        ("max_inflight", Json::Num(cfg.max_inflight as f64)),
    ])
}

fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Append one entry (config + per-method reports + git rev + timestamp) to
/// the schema-versioned trajectory file at `path`, creating it if absent.
///
/// The file is `{"schema": 1, "entries": [...]}`; successive PRs append
/// comparable datapoints rather than overwriting history.  An existing
/// file that fails to parse or carries a different schema is an error —
/// never silently clobbered.
pub fn append_trajectory(path: &Path, config: Json, reports: &[MethodReport]) -> Result<()> {
    let mut entries: Vec<Json> = match std::fs::read_to_string(path) {
        Ok(text) => {
            let doc = parse(&text)
                .with_context(|| format!("existing {} is not valid JSON", path.display()))?;
            let schema = doc.get("schema").and_then(|s| s.as_f64());
            anyhow::ensure!(
                schema == Some(TRAJECTORY_SCHEMA),
                "{}: schema {:?} != {TRAJECTORY_SCHEMA} (refusing to mix)",
                path.display(),
                schema,
            );
            doc.get("entries").and_then(|e| e.as_arr()).map(|a| a.to_vec()).unwrap_or_default()
        }
        // Only a genuinely absent file starts a fresh history; any other
        // read failure (corrupt UTF-8, permissions, transient IO) must not
        // silently clobber the existing trajectory on the write below.
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => {
            return Err(e).with_context(|| format!("read {}", path.display()));
        }
    };
    let unix = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    entries.push(Json::obj(vec![
        ("git_rev", Json::Str(git_rev())),
        ("unix_time", Json::Num(unix)),
        ("config", config),
        ("methods", Json::Arr(reports.iter().map(report_json).collect())),
    ]));
    let doc = Json::obj(vec![
        ("schema", Json::Num(TRAJECTORY_SCHEMA)),
        ("entries", Json::Arr(entries)),
    ]);
    // Atomic replace: write a sibling temp file and rename it over the
    // trajectory.  A truncating in-place write could destroy the whole
    // append-only history on a mid-write kill or a full disk.
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, doc.to_string() + "\n")
        .with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} over {}", tmp.display(), path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fully-populated row with easy-to-serialize values.  One field
    /// (`queue_wait_ms_mean`) is NaN on purpose so the bytes also pin the
    /// NaN→null mapping.
    fn sample_report() -> MethodReport {
        MethodReport {
            method: "spa".into(),
            requests: 2,
            errors: 0,
            dropped: 1,
            measured_s: 2.0,
            offered_qps: 4.0,
            achieved_qps: 1.5,
            tps: 32.0,
            ttft: Some(Summary {
                n: 2,
                mean: 60.0,
                std: 10.0,
                min: 50.0,
                max: 70.0,
                p50: 50.0,
                p90: 70.0,
                p99: 70.0,
            }),
            latency: None,
            wall: None,
            mean_inflight: 0.5,
            queue_wait_ms_mean: f64::NAN,
            refreshes: 3.0,
            steps: 100.0,
            refresh_rate: 0.03,
            partial_refreshes: 5.0,
            rows_invalidated: 1.0,
            scheduled_row_refreshes: 2.0,
            schedule_refits: 0.0,
            tier_switches: 0.0,
            budget_tier: 0.0,
            adaptive: true,
            upload_us: 10.0,
            execute_us: 20.0,
            collect_us: 30.0,
            sample_us: 40.0,
            serialize_us: 50.0,
            step_wall_us: 60.0,
            rows_uploaded: 7.0,
            rows_skipped: 8.0,
            prefix_hits: 1.0,
            prefix_misses: 1.0,
            prefix_evictions: 0.0,
            prefix_purges: 0.0,
            warm_admissions: 1.0,
            affinity_dispatches: 2.0,
            pages_resident: 4.0,
            pages_evicted: 1.0,
            pages_reclaimed: 2.0,
            stale_served: 3.0,
            rate_limited: 0.0,
            degraded_entries: 1.0,
            degraded_exits: 1.0,
            degraded_mode: 0.0,
            drift_debt_peak: 9.0,
            paged: false,
            prefix_hit_rate: None,
            warm_ttft_ms: None,
            per_worker_completed: vec![(0, 2.0)],
            scenario: None,
            slo: None,
            latency_samples: Vec::new(),
        }
    }

    /// Satellite regression: the trajectory row serializes to **the exact
    /// bytes** it did when this code lived inside `bench::loadgen` — key
    /// names, key order, integral-float rendering, NaN→null, and the
    /// presence rules for the optional prefix/paged/scenario columns are
    /// all frozen here.  Any diff in this string is a schema change and
    /// must bump [`TRAJECTORY_SCHEMA`].
    #[test]
    fn trajectory_row_bytes_are_frozen() {
        let base = concat!(
            "{\"method\":\"spa\",\"requests\":2,\"errors\":0,\"dropped\":1,",
            "\"measured_s\":2,\"offered_qps\":4,\"achieved_qps\":1.5,\"tps\":32,",
            "\"ttft_ms\":{\"n\":2,\"mean\":60,\"min\":50,\"p50\":50,\"p90\":70,",
            "\"p99\":70,\"max\":70},\"latency_ms\":null,\"wall_ms\":null,",
            "\"mean_inflight\":0.5,\"queue_wait_ms_mean\":null,\"refreshes\":3,",
            "\"steps\":100,\"refresh_rate\":0.03,\"partial_refreshes\":5,",
            "\"rows_invalidated\":1,\"scheduled_row_refreshes\":2,",
            "\"schedule_refits\":0,\"tier_switches\":0,\"budget_tier\":0,",
            "\"adaptive\":true,\"ledger\":{\"upload_us\":10,\"execute_us\":20,",
            "\"collect_us\":30,\"sample_us\":40,\"serialize_us\":50,",
            "\"step_wall_us\":60,\"rows_uploaded\":7,\"rows_skipped\":8},",
            "\"per_worker_completed\":[{\"worker\":0,\"completed\":2}]",
        );
        let r = sample_report();
        assert_eq!(report_json(&r).to_string(), format!("{base}}}"));

        // Stamping the optional column families appends exactly these keys
        // in exactly this order — nothing in the base row moves.
        let mut warm = sample_report();
        warm.prefix_hit_rate = Some(0.5);
        warm.warm_ttft_ms = Some(12.5);
        warm.paged = true;
        warm.scenario = Some("chat".into());
        let tail = concat!(
            ",\"prefix_hit_rate\":0.5,\"prefix_hits\":1,\"prefix_misses\":1,",
            "\"prefix_evictions\":0,\"prefix_purges\":0,\"warm_admissions\":1,",
            "\"affinity_dispatches\":2,\"warm_ttft_ms\":12.5,",
            "\"pages_resident\":4,\"pages_evicted\":1,\"pages_reclaimed\":2,",
            "\"stale_served\":3,\"rate_limited\":0,\"degraded_entries\":1,",
            "\"degraded_exits\":1,\"degraded_mode\":0,\"drift_debt_peak\":9,",
            "\"scenario\":\"chat\"}",
        );
        assert_eq!(report_json(&warm).to_string(), format!("{base}{tail}"));
    }
}
