//! Bench harness substrate (criterion is unavailable offline).
//!
//! Provides warmup+measure timing loops and an aligned table printer that
//! mirrors the paper's table layout (TPS with speedup factors, TTFT,
//! accuracy with binomial CIs).  Every `rust/benches/bench_*.rs` target uses
//! this; `cargo bench` runs them all.  [`loadgen`] is the serving-path
//! complement: open/closed-loop traffic through the TCP frontend rather
//! than closed timing loops (DESIGN.md §10).

pub mod loadgen;
pub mod report;
pub mod runner;
pub mod scenario;
pub mod stub;

use std::time::Instant;

use crate::util::stats::{binomial_ci95, Summary};

/// Time `f` for `iters` iterations after `warmup` ones; returns per-iter ms.
pub fn time_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Summary::of(&samples)
}

/// Paper-style TPS cell: absolute value plus speedup over the baseline.
pub fn fmt_tps(tps: f64, baseline_tps: f64) -> String {
    if baseline_tps > 0.0 {
        format!("{tps:.2} ({:.1}x)", tps / baseline_tps)
    } else {
        format!("{tps:.2}")
    }
}

/// Paper-style accuracy cell: percentage with a binomial 95% CI.
pub fn fmt_acc(acc: f64, n: usize) -> String {
    format!("{:.2} (±{:.2})", acc * 100.0, binomial_ci95(acc, n) * 100.0)
}

/// Aligned ASCII table printer.
pub struct Table {
    /// Printed as `== title ==` above the table.
    pub title: String,
    /// Column headings; every row must match this arity.
    pub headers: Vec<String>,
    /// Cell text, row-major.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with the given title and column headings.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render the aligned table as text.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], width: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &width));
            out.push('\n');
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Append the rendered table to a results file (bench log).
    pub fn append_to(&self, path: &str) {
        use std::io::Write;
        if let Ok(mut f) =
            std::fs::OpenOptions::new().create(true).append(true).open(path)
        {
            let _ = writeln!(f, "{}", self.render());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["method", "tps"]);
        t.row(vec!["baseline".into(), "29.67 (1.0x)".into()]);
        t.row(vec!["ours".into(), "190.73 (6.4x)".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("baseline"));
        let lines: Vec<&str> = s.lines().collect();
        // header and rows aligned to same column start
        let hpos = lines[2].find("tps").unwrap();
        assert_eq!(lines[4].find("29.67"), Some(hpos));
    }

    #[test]
    fn timing_returns_iters() {
        let s = time_ms(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_tps(60.0, 30.0), "60.00 (2.0x)");
        assert!(fmt_acc(0.5, 16).starts_with("50.00"));
    }
}
