//! Trace-driven scenario suite with per-scenario SLO reporting
//! (DESIGN.md §10).
//!
//! The load shapes in [`super::loadgen`] answer "how much load" — this
//! module answers "load shaped like *what*".  The paper's throughput
//! claims were measured under one synthetic request shape; cache dynamics
//! differ sharply between prompt-dominant and response-dominant traffic,
//! so before any speedup claim is believable the serving path has to hold
//! up under production-shaped workloads:
//!
//! * **chat** — multi-turn sessions that resubmit their whole transcript
//!   as the prompt every turn (the shape future prefix-reuse work feeds
//!   on): prompt-dominant, short replies, think-time gaps.
//! * **infill** — arbitrary-order mask layouts, the DLM-native workload no
//!   AR server can express: each request ships a `template` +
//!   `mask_offsets` spec (protocol v2) and the scenario *verifies* the
//!   committed positions match the requested non-contiguous layout.
//! * **mixed** — a short-chat + long-doc population at Poisson arrivals,
//!   the heterogeneity a single request shape hides.
//! * **trace** — bursty replay from a recorded trace file (JSON-lines;
//!   `--trace` replays, `--record-trace` captures the synthesized one), so
//!   a production arrival pattern can be replayed verbatim.
//! * **cancel-storm** — interactive traffic that cancels most of what it
//!   submits mid-decode, exercising slot reclamation under load.
//! * **overload** — an open-loop ramp past the service knee: a short-chat
//!   + long-doc mix whose arrival rate climbs linearly to a peak,
//!   recording sustained goodput, the stale-served fraction and the
//!   degraded-mode entry/exit counters — the acceptance workload for the
//!   paged slot-memory manager + overload controller (DESIGN.md §12).
//!
//! Every scenario runs artifact-free against the `bench::stub` workers
//! (`bench-serve --stub --scenario <name>`) and reports **SLO attainment**
//! rather than bare means: p99 TTFT against a target, goodput (completions
//! under a latency deadline) — recorded as a tagged trajectory entry whose
//! schema-versioned `slo` block CI asserts on.  All request content,
//! arrival times and cancel choices derive from `--seed`, so two same-seed
//! runs issue identical request schedules.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::server::{self, Client, GenRequest};
use crate::util::cli::Args;
use crate::util::json::{parse, Json};
use crate::util::rng::Rng;

use super::loadgen::{
    aggregate, finite_or_null, sleep_until, spawn_stub_server, stamp_paged_columns,
    stamp_prefix_columns, ArrivalMode, LoadGenConfig, MethodReport, Obs, PolicyFlags,
};

/// Schema version stamped into every `slo` block; bump on any breaking
/// change to the block layout (readers must check it).
pub const SLO_SCHEMA: f64 = 1.0;

/// Generated-region length of a chat reply (tokens).
const CHAT_REPLY_LEN: usize = 8;

/// Transcript budget (chars) resubmitted as the chat prompt.  The stub
/// serves at `STUB_SEQ_LEN = 128`: 96 prompt chars + BOS + an 8-token
/// reply leaves headroom, and overflowing transcripts slide (front-trim)
/// exactly like a context-window truncation would.
const CHAT_PROMPT_BUDGET: usize = 96;

/// Generated-region length of a cancel-storm request — long enough
/// (64 tokens at 4 commits/step) that cancels land mid-decode.
const STORM_GEN_LEN: usize = 64;

/// Streaming requests per cancel-storm burst.
const STORM_BURST: usize = 4;

/// Mixed-population offered load when the run didn't pass `--qps`.
const MIXED_DEFAULT_QPS: f64 = 20.0;

/// Overload-ramp peak when the run didn't pass `--qps` — far past the
/// stub's service knee, so the ramp actually overloads.
const OVERLOAD_DEFAULT_PEAK_QPS: f64 = 400.0;

/// Distinct session keys the overload ramp's short-chat population cycles
/// through — the identities the degraded-mode token buckets shape on.
const OVERLOAD_SESSIONS: usize = 8;

/// Prompt alphabet for synthesized traffic — a strict subset of the model
/// charset, so every synthesized prompt encodes.
const PROMPT_CHARS: &[u8] = b"0123456789abcdefghijklmnopqrstuvwxyz ";

// ---------------------------------------------------------------------------
// Scenario configuration
// ---------------------------------------------------------------------------

/// The six traffic shapes of the scenario suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Multi-turn chat sessions resubmitting their transcript each turn.
    Chat,
    /// Arbitrary-order infilling via per-request mask layouts.
    Infill,
    /// Short-chat + long-doc population at Poisson arrivals.
    Mixed,
    /// Bursty replay from a recorded (or synthesized) trace file.
    Trace,
    /// Submit-then-cancel bursts exercising slot reclamation.
    CancelStorm,
    /// Open-loop ramp past the knee: goodput + degraded-mode evidence.
    Overload,
}

impl ScenarioKind {
    /// Every scenario, in CLI/CI order.
    pub const ALL: [ScenarioKind; 6] = [
        ScenarioKind::Chat,
        ScenarioKind::Infill,
        ScenarioKind::Mixed,
        ScenarioKind::Trace,
        ScenarioKind::CancelStorm,
        ScenarioKind::Overload,
    ];

    /// The `--scenario` spelling (also the trajectory tag).
    pub fn name(self) -> &'static str {
        match self {
            ScenarioKind::Chat => "chat",
            ScenarioKind::Infill => "infill",
            ScenarioKind::Mixed => "mixed",
            ScenarioKind::Trace => "trace",
            ScenarioKind::CancelStorm => "cancel-storm",
            ScenarioKind::Overload => "overload",
        }
    }

    /// Inverse of [`ScenarioKind::name`]; `None` for unknown spellings.
    pub fn from_name(s: &str) -> Option<ScenarioKind> {
        ScenarioKind::ALL.into_iter().find(|k| k.name() == s)
    }

    /// Default SLO targets, sized for the stub timing (2 ms steps) so an
    /// unloaded CI run attains them; real hardware overrides via
    /// `--slo-ttft`/`--slo-deadline`.
    fn default_slo(self) -> SloTargets {
        match self {
            ScenarioKind::Chat | ScenarioKind::Infill => {
                SloTargets { ttft_p99_ms: 250.0, deadline_ms: 1000.0 }
            }
            ScenarioKind::Mixed
            | ScenarioKind::Trace
            | ScenarioKind::CancelStorm
            | ScenarioKind::Overload => {
                SloTargets { ttft_p99_ms: 500.0, deadline_ms: 2000.0 }
            }
        }
    }
}

/// The two thresholds a scenario is judged against.
#[derive(Debug, Clone, Copy)]
pub struct SloTargets {
    /// p99 time-to-first-token must come in under this (ms).
    pub ttft_p99_ms: f64,
    /// A completion counts toward goodput only under this latency (ms).
    pub deadline_ms: f64,
}

/// Everything one scenario run is parameterised by (on top of the base
/// [`LoadGenConfig`], which still supplies warmup/duration/seed/qps).
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Which traffic shape to drive.
    pub kind: ScenarioKind,
    /// SLO thresholds the report is judged against.
    pub slo: SloTargets,
    /// Concurrent sessions (chat / infill clients / storm connections).
    pub sessions: usize,
    /// Turns per chat conversation before the transcript resets.
    pub turns: usize,
    /// Trace scenario: replay this file instead of synthesizing.
    pub trace: Option<PathBuf>,
    /// Trace scenario: record the replayed/synthesized trace here.
    pub record_trace: Option<PathBuf>,
    /// Overload scenario: peak of the arrival-rate ramp (rps).  `--qps`
    /// overrides; `None` → [`OVERLOAD_DEFAULT_PEAK_QPS`].  The base
    /// config's default open-loop rate is *not* reused here — an unflagged
    /// overload run must still ramp past the knee.
    pub peak_qps: Option<f64>,
}

impl ScenarioConfig {
    /// Build from CLI flags — `--slo-ttft MS`, `--slo-deadline MS`,
    /// `--sessions N`, `--turns N` (chat), `--trace FILE` /
    /// `--record-trace FILE` (trace).  Strict like the rest of the bench
    /// CLI: malformed values and flags that cannot apply to `kind` are
    /// errors, never silent fallbacks — a typo'd threshold must not record
    /// the wrong SLO verdict into the trajectory.
    pub fn from_args(kind: ScenarioKind, args: &Args) -> Result<ScenarioConfig> {
        let d = kind.default_slo();
        let ms = |key: &str, default: f64| -> Result<f64> {
            match args.get(key) {
                None => Ok(default),
                Some(s) => {
                    let v: f64 = s.trim().parse().map_err(|_| {
                        anyhow::anyhow!("bad --{key} '{s}' (want milliseconds)")
                    })?;
                    anyhow::ensure!(
                        v.is_finite() && v > 0.0,
                        "--{key} must be positive (got {s})"
                    );
                    Ok(v)
                }
            }
        };
        let scn = ScenarioConfig {
            kind,
            slo: SloTargets {
                ttft_p99_ms: ms("slo-ttft", d.ttft_p99_ms)?,
                deadline_ms: ms("slo-deadline", d.deadline_ms)?,
            },
            sessions: args.strict_count("sessions")?.unwrap_or(4),
            turns: args.strict_count("turns")?.unwrap_or(4),
            trace: args.get("trace").map(PathBuf::from),
            record_trace: args.get("record-trace").map(PathBuf::from),
            // `--qps` is validated (and recorded) by LoadGenConfig; here it
            // only needs re-reading as the overload ramp's peak override.
            peak_qps: match args.get("qps") {
                Some(s) => s.trim().parse::<f64>().ok().filter(|q| q.is_finite() && *q > 0.0),
                None => None,
            },
        };
        if kind != ScenarioKind::Trace {
            anyhow::ensure!(
                scn.trace.is_none() && scn.record_trace.is_none(),
                "--trace/--record-trace apply only to --scenario trace"
            );
        }
        if kind != ScenarioKind::Chat {
            anyhow::ensure!(
                args.get("turns").is_none(),
                "--turns applies only to --scenario chat"
            );
        }
        Ok(scn)
    }
}

// ---------------------------------------------------------------------------
// SLO report
// ---------------------------------------------------------------------------

/// Per-scenario SLO attainment, recorded as the schema-versioned `slo`
/// block of a tagged trajectory row (see [`slo_json`]).
#[derive(Debug, Clone)]
pub struct SloReport {
    /// The p99-TTFT target judged against (ms).
    pub ttft_p99_target_ms: f64,
    /// Measured p99 TTFT (ms); `None` when nothing completed.
    pub ttft_p99_ms: Option<f64>,
    /// `ttft_p99_ms <= target`; `None` when unmeasurable.
    pub ttft_ok: Option<bool>,
    /// The goodput latency deadline (ms).
    pub deadline_ms: f64,
    /// Measured-window completions under the deadline.
    pub good: usize,
    /// Measured-window completions total (errors excluded).
    pub total: usize,
    /// `good / total`; `None` when nothing completed.
    pub attainment: Option<f64>,
    /// Deadline-respecting completions per second of measured window.
    pub goodput_rps: f64,
    /// Scenario-specific evidence counters (e.g. infill `layout_ok`).
    pub extras: Vec<(&'static str, f64)>,
}

/// The `slo` block of a scenario trajectory row.  Schema-versioned and
/// NaN-guarded like every other trajectory float.
pub fn slo_json(s: &SloReport) -> Json {
    let mut pairs = vec![
        ("schema", Json::Num(SLO_SCHEMA)),
        ("ttft_p99_target_ms", finite_or_null(s.ttft_p99_target_ms)),
        ("ttft_p99_ms", match s.ttft_p99_ms {
            Some(v) => finite_or_null(v),
            None => Json::Null,
        }),
        ("ttft_ok", match s.ttft_ok {
            Some(b) => Json::Bool(b),
            None => Json::Null,
        }),
        ("deadline_ms", finite_or_null(s.deadline_ms)),
        ("good", Json::Num(s.good as f64)),
        ("total", Json::Num(s.total as f64)),
        ("deadline_attainment", match s.attainment {
            Some(v) => finite_or_null(v),
            None => Json::Null,
        }),
        ("goodput_rps", finite_or_null(s.goodput_rps)),
    ];
    for &(k, v) in &s.extras {
        pairs.push((k, finite_or_null(v)));
    }
    Json::obj(pairs)
}

/// Print one SLO verdict line per scenario report, under the standard
/// bench table.
pub fn print_slo(reports: &[MethodReport]) {
    for r in reports {
        let (Some(name), Some(s)) = (&r.scenario, &r.slo) else { continue };
        let p99 = s
            .ttft_p99_ms
            .map(|v| format!("{v:.0}ms"))
            .unwrap_or_else(|| "-".to_string());
        let ok = match s.ttft_ok {
            Some(true) => "ok",
            Some(false) => "MISS",
            None => "n/a",
        };
        let att = s
            .attainment
            .map(|a| format!("{:.1}%", a * 100.0))
            .unwrap_or_else(|| "-".to_string());
        println!(
            "slo {name} {}: ttft p99 {p99} vs {:.0}ms [{ok}]  \
             deadline {:.0}ms {}/{} ({att})  goodput {:.2} rps",
            r.method, s.ttft_p99_target_ms, s.deadline_ms, s.good, s.total, s.goodput_rps
        );
    }
}

// ---------------------------------------------------------------------------
// Trace file format
// ---------------------------------------------------------------------------

/// One arrival of a recorded trace: at `at_ms` after run start, issue
/// `prompt` asking for `gen_len` generated tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Arrival offset from run start (ms) — warmup included, so a trace
    /// carries its own warmup traffic.
    pub at_ms: f64,
    /// Prompt text (must encode under the server charset).
    pub prompt: String,
    /// Generated-region length (tokens, > 0).
    pub gen_len: usize,
    /// Stable session key, when the arrival belongs to a conversation
    /// (prefix-cache affinity keys on it).  Absent in traces recorded
    /// before the field existed — old files still replay.
    pub session: Option<String>,
}

/// Write `events` as the JSON-lines trace format (one
/// `{"at_ms":..,"prompt":..,"gen_len":..}` object per line; `session`
/// rides along only when present, so session-free traces stay
/// byte-compatible with the original format).
pub fn write_trace(path: &Path, events: &[TraceEvent]) -> Result<()> {
    let mut text = String::new();
    for e in events {
        let mut pairs = vec![
            ("at_ms", Json::Num(e.at_ms)),
            ("prompt", Json::str(&e.prompt)),
            ("gen_len", Json::int(e.gen_len as i64)),
        ];
        if let Some(s) = &e.session {
            pairs.push(("session", Json::str(s)));
        }
        let line = Json::obj(pairs);
        text.push_str(&line.to_string());
        text.push('\n');
    }
    std::fs::write(path, text).with_context(|| format!("write trace {}", path.display()))
}

/// Read a JSON-lines trace, strictly: every non-empty line must carry a
/// finite non-negative `at_ms`, a string `prompt` and a positive integer
/// `gen_len` — a malformed trace errors with its line number rather than
/// silently replaying the wrong load.  Events are returned in arrival
/// order regardless of on-disk order.
pub fn read_trace(path: &Path) -> Result<Vec<TraceEvent>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read trace {}", path.display()))?;
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let at = format!("{}:{}", path.display(), ln + 1);
        let j = parse(line).with_context(|| format!("{at}: not valid JSON"))?;
        let at_ms = j
            .get("at_ms")
            .and_then(|x| x.as_f64())
            .ok_or_else(|| anyhow::anyhow!("{at}: missing numeric at_ms"))?;
        anyhow::ensure!(
            at_ms.is_finite() && at_ms >= 0.0,
            "{at}: at_ms must be finite and non-negative"
        );
        let prompt = j
            .get("prompt")
            .and_then(|x| x.as_str())
            .ok_or_else(|| anyhow::anyhow!("{at}: missing string prompt"))?
            .to_string();
        let gen_len = j
            .get("gen_len")
            .and_then(|x| x.as_usize())
            .filter(|&g| g > 0)
            .ok_or_else(|| anyhow::anyhow!("{at}: gen_len must be a positive integer"))?;
        let session = j.get("session").and_then(|x| x.as_str()).map(String::from);
        out.push(TraceEvent { at_ms, prompt, gen_len, session });
    }
    out.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
    Ok(out)
}

// ---------------------------------------------------------------------------
// Seeded request synthesis
// ---------------------------------------------------------------------------

/// A random prompt of `lo..hi` chars over [`PROMPT_CHARS`].
fn synth_prompt(rng: &mut Rng, lo: usize, hi: usize) -> String {
    let n = rng.range(lo, hi);
    (0..n).map(|_| PROMPT_CHARS[rng.range(0, PROMPT_CHARS.len())] as char).collect()
}

/// Draw one request shape from the mixed population: 70% short chat
/// (small prompt, short reply), 30% long-doc (long prompt, long reply).
/// Both fit the stub's 128-token rows with headroom.
fn synth_shape(rng: &mut Rng) -> (String, usize) {
    if rng.bool(0.7) {
        (synth_prompt(rng, 6, 14), 8 + rng.range(0, 9))
    } else {
        (synth_prompt(rng, 28, 46), 48 + rng.range(0, 17))
    }
}

/// One chat-turn utterance (charset-safe, a handful of chars so several
/// turns of transcript fit the stub rows).
fn chat_utterance(rng: &mut Rng) -> String {
    format!("#q {}+{}=?#a ", rng.range(0, 10), rng.range(0, 10))
}

/// Front-trim `h` to its last `budget` bytes (transcripts are ASCII-only
/// by construction) — the sliding context window of a chat session.
fn trim_history(h: &mut String, budget: usize) {
    if h.len() > budget {
        let cut = h.len() - budget;
        h.drain(..cut);
    }
}

/// One seeded infill spec: a template plus the ascending offsets to mask.
/// The layout is guaranteed **non-contiguous** (mask–hole–mask at the
/// front), the shape a left-to-right semi-AR block scheduler cannot
/// produce — so a passing layout check is real evidence of arbitrary-order
/// decode.
pub(crate) fn infill_spec(rng: &mut Rng) -> (String, Vec<usize>) {
    let len = rng.range(12, 33);
    let template = synth_prompt(rng, len, len + 1);
    let mut mask: Vec<bool> = (0..len).map(|_| rng.bool(0.5)).collect();
    mask[0] = true;
    mask[1] = false;
    mask[2] = true;
    let offsets: Vec<usize> =
        mask.iter().enumerate().filter_map(|(i, &m)| m.then_some(i)).collect();
    (template, offsets)
}

/// Synthesize the mixed-population Poisson trace at `qps` over the whole
/// (warmup + duration) window.  Pure function of the seeded inputs — the
/// reproducibility regression leans on this.
pub(crate) fn synth_mixed_trace(cfg: &LoadGenConfig, qps: f64) -> Vec<TraceEvent> {
    let mut rng = Rng::new(cfg.seed ^ 0x3317_AB1E);
    let total_ms = (cfg.warmup + cfg.duration).as_secs_f64() * 1e3;
    let mut at = 0.0;
    let mut out = Vec::new();
    loop {
        at += -(1.0 - rng.f64()).ln() * 1e3 / qps;
        if at >= total_ms {
            return out;
        }
        let (prompt, gen_len) = synth_shape(&mut rng);
        out.push(TraceEvent { at_ms: at, prompt, gen_len, session: None });
    }
}

/// Synthesize the default bursty trace: exponential gaps between bursts
/// of 2–6 near-simultaneous arrivals, mixed-population shapes.  Pure
/// function of the seeded inputs.
pub(crate) fn synth_bursty_trace(cfg: &LoadGenConfig) -> Vec<TraceEvent> {
    let mut rng = Rng::new(cfg.seed ^ 0x00B0_0575);
    let total_ms = (cfg.warmup + cfg.duration).as_secs_f64() * 1e3;
    let mut at = 0.0;
    let mut out = Vec::new();
    loop {
        at += 120.0 - (1.0 - rng.f64()).ln() * 240.0;
        if out.is_empty() {
            // Clamp the first burst into the window: short smoke runs must
            // always offer load, whatever the first exponential draw says.
            at = at.min(total_ms * 0.5);
        }
        if at >= total_ms {
            return out;
        }
        let burst = rng.range(2, 7);
        for i in 0..burst {
            let (prompt, gen_len) = synth_shape(&mut rng);
            // Spread burst members by 2 ms so the wire sees a stampede,
            // not a single serialized arrival.
            out.push(TraceEvent { at_ms: at + 2.0 * i as f64, prompt, gen_len, session: None });
        }
    }
}

/// Synthesize the overload ramp: deterministic arrivals whose rate climbs
/// linearly from `peak / 10` to `peak` over the whole (warmup + duration)
/// window.  The population is the mixed shape — 70% short chat carrying
/// one of [`OVERLOAD_SESSIONS`] stable session keys (the identities the
/// degraded-mode token buckets shape on), 30% long-doc — so the summed
/// worst-case `[B, N]` footprint of a full batch exceeds any page budget
/// smaller than `batch × n_pages` frames.  Pure function of the seeded
/// inputs, like the other synthesizers.
pub(crate) fn synth_overload_trace(cfg: &LoadGenConfig, peak: f64) -> Vec<TraceEvent> {
    let mut rng = Rng::new(cfg.seed ^ 0x04E1_10AD);
    let total_ms = (cfg.warmup + cfg.duration).as_secs_f64() * 1e3;
    let lo = peak / 10.0;
    let mut at = 0.0;
    let mut out = Vec::new();
    let mut k = 0usize;
    loop {
        // Instantaneous rate at the current offset; the gap to the next
        // arrival shrinks as the ramp climbs.
        let rate = lo + (peak - lo) * (at / total_ms).min(1.0);
        at += 1e3 / rate;
        if at >= total_ms {
            return out;
        }
        let (prompt, gen_len, session) = if rng.bool(0.7) {
            (
                synth_prompt(&mut rng, 6, 14),
                8 + rng.range(0, 9),
                Some(format!("ovl-{}-{}", cfg.seed, k % OVERLOAD_SESSIONS)),
            )
        } else {
            (synth_prompt(&mut rng, 28, 46), 48 + rng.range(0, 17), None)
        };
        k += 1;
        out.push(TraceEvent { at_ms: at, prompt, gen_len, session });
    }
}

// ---------------------------------------------------------------------------
// Scenario drivers
// ---------------------------------------------------------------------------

/// Shared evidence counters the generator threads accumulate; folded into
/// the report's `slo.extras`.
#[derive(Default)]
struct Evidence {
    /// Cancel ops sent (cancel-storm).
    cancels_issued: AtomicUsize,
    /// `cancelled` terminal frames observed (cancel-storm).
    cancels_acked: AtomicUsize,
    /// Infill requests whose committed positions were checked.
    layout_checked: AtomicUsize,
    /// Of those, how many matched the requested mask layout exactly.
    layout_ok: AtomicUsize,
    /// Chat turns completed.
    turns: AtomicUsize,
    /// Trace/mixed events actually issued (admitted past the cap).
    replayed: AtomicUsize,
}

/// A prepared scenario: the (possibly adjusted) load config plus the
/// concrete work to drive.
enum Plan {
    /// `sessions` chat sessions of `turns`-turn conversations.
    Chat { sessions: usize, turns: usize },
    /// `clients` closed-loop infill clients.
    Infill { clients: usize },
    /// Replay `events` at their recorded arrival times.
    Replay { events: Vec<TraceEvent> },
    /// `sessions` connections running submit-then-cancel bursts.
    CancelStorm { sessions: usize },
}

/// Resolve a scenario into a concrete [`Plan`], adjusting the load config
/// so connection sizing and the recorded `offered_qps` describe what the
/// scenario actually drives.  Trace reads/records happen here — before
/// any server exists — so a bad trace file fails fast.
fn prepare(cfg: &LoadGenConfig, scn: &ScenarioConfig) -> Result<(LoadGenConfig, Plan)> {
    let mut cfg = cfg.clone();
    let sessions = scn.sessions.max(1);
    let plan = match scn.kind {
        ScenarioKind::Chat => {
            cfg.mode = ArrivalMode::Closed { clients: sessions };
            Plan::Chat { sessions, turns: scn.turns.max(1) }
        }
        ScenarioKind::Infill => {
            cfg.mode = ArrivalMode::Closed { clients: sessions };
            Plan::Infill { clients: sessions }
        }
        ScenarioKind::Mixed => {
            let qps = match cfg.mode {
                ArrivalMode::Open { qps } => qps,
                _ => MIXED_DEFAULT_QPS,
            };
            let events = synth_mixed_trace(&cfg, qps);
            cfg.mode = ArrivalMode::Open { qps };
            Plan::Replay { events }
        }
        ScenarioKind::Trace => {
            let events = match &scn.trace {
                Some(p) => read_trace(p)?,
                None => synth_bursty_trace(&cfg),
            };
            anyhow::ensure!(
                !events.is_empty(),
                "trace scenario has no arrivals (empty trace / window too short)"
            );
            if let Some(p) = &scn.record_trace {
                write_trace(p, &events)?;
            }
            // Honest offered load: measured-window arrivals over the
            // window (NaN → null when the trace never reaches it).
            let warm_ms = cfg.warmup.as_secs_f64() * 1e3;
            let n = events.iter().filter(|e| e.at_ms >= warm_ms).count();
            let qps = n as f64 / cfg.duration.as_secs_f64().max(1e-9);
            cfg.mode = ArrivalMode::Open { qps: if qps > 0.0 { qps } else { f64::NAN } };
            Plan::Replay { events }
        }
        ScenarioKind::CancelStorm => {
            cfg.mode = ArrivalMode::Closed { clients: sessions };
            Plan::CancelStorm { sessions }
        }
        ScenarioKind::Overload => {
            let peak = scn.peak_qps.unwrap_or(OVERLOAD_DEFAULT_PEAK_QPS);
            let events = synth_overload_trace(&cfg, peak);
            // Recorded offered load is the ramp's peak — the rate the run
            // is judged against, not the (lower) window average.
            cfg.mode = ArrivalMode::Open { qps: peak };
            Plan::Replay { events }
        }
    };
    Ok((cfg, plan))
}

/// An [`Obs`] from a terminal frame / blocking reply `r` (v2 session:
/// anything but a clean `done` is an error for the percentiles).
fn obs_from_reply(r: &Json, issued_s: f64, done_s: f64, wall_ms: f64) -> Obs {
    Obs {
        issued_s,
        done_s,
        wall_ms,
        ttft_ms: r.get("ttft_ms").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
        latency_ms: r.get("latency_ms").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
        decoded: r.get("decoded").and_then(|x| x.as_f64()).unwrap_or(0.0),
        error: r.get("event").and_then(|e| e.as_str()) != Some("done"),
    }
}

/// Multi-turn chat: each session resubmits its growing transcript as the
/// prompt, appends the served reply, and thinks (seeded) between turns.
/// After `turns` turns the conversation resets.
fn spawn_chat(
    addr: &str,
    cfg: &LoadGenConfig,
    t0: Instant,
    obs: &Arc<Mutex<Vec<Obs>>>,
    ev: &Arc<Evidence>,
    sessions: usize,
    turns: usize,
) -> Vec<JoinHandle<()>> {
    let total = cfg.warmup + cfg.duration;
    (0..sessions)
        .map(|s| {
            let addr = addr.to_string();
            let cfg = cfg.clone();
            let obs = Arc::clone(obs);
            let ev = Arc::clone(ev);
            std::thread::spawn(move || {
                let mut rng = Rng::new(
                    cfg.seed ^ (0xC4A7 + s as u64).wrapping_mul(0x9E3779B97F4A7C15),
                );
                let mut client = match Client::connect(&addr) {
                    Ok(c) => c,
                    Err(_) => return,
                };
                // Stable per-conversation key: seed-scoped so two runs of
                // the same seed produce identical session identities, and
                // reused across turns — the handle prefix-cache affinity
                // routes on.
                let session_key = format!("chat-{}-{s}", cfg.seed);
                let mut history = String::new();
                let mut turn = 0usize;
                while t0.elapsed() < total {
                    if turn >= turns {
                        history.clear();
                        turn = 0;
                    }
                    // The whole transcript so far rides along as the
                    // prompt — exactly what prefix reuse would see.
                    history.push_str(&chat_utterance(&mut rng));
                    trim_history(&mut history, CHAT_PROMPT_BUDGET);
                    let req = GenRequest {
                        prompt: history.clone(),
                        gen_len: Some(CHAT_REPLY_LEN),
                        session: Some(session_key.clone()),
                        ..GenRequest::default()
                    };
                    let issued_s = t0.elapsed().as_secs_f64();
                    let w0 = Instant::now();
                    let Ok(r) = client.generate_opts(&req) else { return };
                    obs.lock().unwrap().push(obs_from_reply(
                        &r,
                        issued_s,
                        t0.elapsed().as_secs_f64(),
                        w0.elapsed().as_secs_f64() * 1e3,
                    ));
                    if let Some(t) = r.get("text").and_then(|t| t.as_str()) {
                        history.push_str(t);
                    }
                    ev.turns.fetch_add(1, Ordering::SeqCst);
                    turn += 1;
                    std::thread::sleep(Duration::from_millis(rng.range(5, 40) as u64));
                }
            })
        })
        .collect()
}

/// Infilling: closed-loop clients streaming seeded non-contiguous mask
/// layouts, verifying per request that the union of streamed `positions`
/// is exactly the requested layout (absolute positions).
fn spawn_infill(
    addr: &str,
    cfg: &LoadGenConfig,
    t0: Instant,
    obs: &Arc<Mutex<Vec<Obs>>>,
    ev: &Arc<Evidence>,
    clients: usize,
) -> Vec<JoinHandle<()>> {
    let total = cfg.warmup + cfg.duration;
    (0..clients)
        .map(|c| {
            let addr = addr.to_string();
            let cfg = cfg.clone();
            let obs = Arc::clone(obs);
            let ev = Arc::clone(ev);
            std::thread::spawn(move || {
                let mut rng = Rng::new(
                    cfg.seed ^ (0x1F11 + c as u64).wrapping_mul(0x9E3779B97F4A7C15),
                );
                let mut client = match Client::connect(&addr) {
                    Ok(c) => c,
                    Err(_) => return,
                };
                // Fixed prompt ⇒ known prompt_len (BOS + 5 chars) for the
                // offset→absolute-position translation below.
                let prompt = "fill:";
                let prompt_len = 1 + prompt.len();
                while t0.elapsed() < total {
                    let (template, offsets) = infill_spec(&mut rng);
                    let req = GenRequest {
                        prompt: prompt.to_string(),
                        template: Some(template),
                        mask_offsets: Some(offsets.clone()),
                        stream: true,
                        ..GenRequest::default()
                    };
                    let issued_s = t0.elapsed().as_secs_f64();
                    let w0 = Instant::now();
                    let Ok(pending) = client.submit(&req) else { return };
                    let mut positions: Vec<i64> = Vec::new();
                    let terminal = loop {
                        let Ok(f) = pending.next_event() else { return };
                        if server::is_terminal(&f) {
                            break f;
                        }
                        if let Some(ps) = f.get("positions").and_then(|p| p.as_arr()) {
                            positions.extend(ps.iter().filter_map(|p| p.as_i64()));
                        }
                    };
                    let o = obs_from_reply(
                        &terminal,
                        issued_s,
                        t0.elapsed().as_secs_f64(),
                        w0.elapsed().as_secs_f64() * 1e3,
                    );
                    // The acceptance evidence: committed positions must be
                    // exactly the requested (non-contiguous) layout.
                    let mut expect: Vec<i64> =
                        offsets.iter().map(|&o| (prompt_len + o) as i64).collect();
                    expect.sort_unstable();
                    positions.sort_unstable();
                    positions.dedup();
                    ev.layout_checked.fetch_add(1, Ordering::SeqCst);
                    if !o.error && positions == expect {
                        ev.layout_ok.fetch_add(1, Ordering::SeqCst);
                    }
                    obs.lock().unwrap().push(o);
                }
            })
        })
        .collect()
}

/// Replay a trace: a dispatcher sleeps to each arrival time and hands the
/// event to a pooled-connection request thread; arrivals past
/// `max_inflight` outstanding are dropped and counted, like the open loop.
fn spawn_replay(
    addr: &str,
    cfg: &LoadGenConfig,
    t0: Instant,
    obs: &Arc<Mutex<Vec<Obs>>>,
    dropped: &Arc<AtomicUsize>,
    ev: &Arc<Evidence>,
    events: Vec<TraceEvent>,
) -> Vec<JoinHandle<()>> {
    let total = cfg.warmup + cfg.duration;
    let addr = addr.to_string();
    let cfg = cfg.clone();
    let obs = Arc::clone(obs);
    let dropped = Arc::clone(dropped);
    let ev = Arc::clone(ev);
    let dispatcher = std::thread::spawn(move || {
        let inflight = Arc::new(AtomicUsize::new(0));
        let pool: Arc<Mutex<Vec<Client>>> = Arc::new(Mutex::new(Vec::new()));
        let mut workers: Vec<JoinHandle<()>> = Vec::new();
        for e in events {
            let at = Duration::from_secs_f64(e.at_ms / 1e3);
            if at >= total {
                break; // the window is the contract; later events don't run
            }
            sleep_until(t0, at);
            if inflight.load(Ordering::SeqCst) >= cfg.max_inflight {
                if at >= cfg.warmup {
                    dropped.fetch_add(1, Ordering::SeqCst);
                }
                continue;
            }
            inflight.fetch_add(1, Ordering::SeqCst);
            ev.replayed.fetch_add(1, Ordering::SeqCst);
            let addr = addr.clone();
            let obs = Arc::clone(&obs);
            let pool = Arc::clone(&pool);
            let inflight = Arc::clone(&inflight);
            workers.push(std::thread::spawn(move || {
                let client = pool.lock().unwrap().pop();
                let client = match client {
                    Some(c) => Some(c),
                    None => Client::connect(&addr).ok(),
                };
                if let Some(mut client) = client {
                    let req = GenRequest {
                        prompt: e.prompt,
                        gen_len: Some(e.gen_len),
                        session: e.session,
                        ..GenRequest::default()
                    };
                    let issued_s = t0.elapsed().as_secs_f64();
                    let w0 = Instant::now();
                    if let Ok(r) = client.generate_opts(&req) {
                        obs.lock().unwrap().push(obs_from_reply(
                            &r,
                            issued_s,
                            t0.elapsed().as_secs_f64(),
                            w0.elapsed().as_secs_f64() * 1e3,
                        ));
                        pool.lock().unwrap().push(client);
                    }
                }
                inflight.fetch_sub(1, Ordering::SeqCst);
            }));
            if workers.len() >= 128 {
                workers.retain(|h| !h.is_finished());
            }
        }
        for h in workers {
            let _ = h.join();
        }
    });
    vec![dispatcher]
}

/// Cancellation storm: each session submits a burst of long streaming
/// requests, lets decode begin, cancels a seeded ~70% of them, and drains
/// every terminal.  Survivors feed the percentiles; cancels feed the
/// evidence counters (and the server's `spa_cancelled_total`).
fn spawn_cancel_storm(
    addr: &str,
    cfg: &LoadGenConfig,
    t0: Instant,
    obs: &Arc<Mutex<Vec<Obs>>>,
    ev: &Arc<Evidence>,
    sessions: usize,
) -> Vec<JoinHandle<()>> {
    let total = cfg.warmup + cfg.duration;
    (0..sessions)
        .map(|s| {
            let addr = addr.to_string();
            let cfg = cfg.clone();
            let obs = Arc::clone(obs);
            let ev = Arc::clone(ev);
            std::thread::spawn(move || {
                let mut rng = Rng::new(
                    cfg.seed ^ (0xCC51 + s as u64).wrapping_mul(0x9E3779B97F4A7C15),
                );
                let mut client = match Client::connect(&addr) {
                    Ok(c) => c,
                    Err(_) => return,
                };
                while t0.elapsed() < total {
                    let mut burst = Vec::new();
                    for _ in 0..STORM_BURST {
                        let req = GenRequest {
                            prompt: chat_utterance(&mut rng),
                            gen_len: Some(STORM_GEN_LEN),
                            stream: true,
                            ..GenRequest::default()
                        };
                        let issued_s = t0.elapsed().as_secs_f64();
                        let w0 = Instant::now();
                        match client.submit(&req) {
                            Ok(p) => burst.push((p, issued_s, w0)),
                            Err(_) => return,
                        }
                    }
                    // Let decode start so cancels land mid-flight, then
                    // cancel a seeded subset.
                    std::thread::sleep(Duration::from_millis(rng.range(2, 10) as u64));
                    for (p, _, _) in &burst {
                        if rng.bool(0.7) {
                            if p.cancel().is_err() {
                                return;
                            }
                            ev.cancels_issued.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    for (p, issued_s, w0) in burst {
                        let Ok(f) = p.wait() else { return };
                        if f.get("event").and_then(|e| e.as_str()) == Some("cancelled") {
                            ev.cancels_acked.fetch_add(1, Ordering::SeqCst);
                        } else {
                            obs.lock().unwrap().push(obs_from_reply(
                                &f,
                                issued_s,
                                t0.elapsed().as_secs_f64(),
                                w0.elapsed().as_secs_f64() * 1e3,
                            ));
                        }
                    }
                }
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Runner
// ---------------------------------------------------------------------------

/// Fold the report + raw observations + evidence into the SLO block.
fn build_slo(
    cfg: &LoadGenConfig,
    scn: &ScenarioConfig,
    r: &MethodReport,
    obs: &[Obs],
    ev: &Evidence,
    end_stats: &str,
) -> SloReport {
    let warm = cfg.warmup.as_secs_f64();
    let measured: Vec<&Obs> =
        obs.iter().filter(|o| o.issued_s >= warm && !o.error).collect();
    let total = measured.len();
    let good = measured
        .iter()
        .filter(|o| o.latency_ms.is_finite() && o.latency_ms <= scn.slo.deadline_ms)
        .count();
    let p99 = r.ttft.as_ref().map(|s| s.p99);
    let count = |a: &AtomicUsize| a.load(Ordering::SeqCst) as f64;
    let extras = match scn.kind {
        ScenarioKind::Chat => vec![("turns", count(&ev.turns))],
        ScenarioKind::Infill => vec![
            ("layout_checked", count(&ev.layout_checked)),
            ("layout_ok", count(&ev.layout_ok)),
        ],
        ScenarioKind::Mixed | ScenarioKind::Trace => {
            vec![("replayed", count(&ev.replayed))]
        }
        // `cancelled_total` is the *server's* count (post-drain absolute
        // scrape; the bench always starts a fresh server) — conservation
        // demands it match both client-side counters exactly.
        ScenarioKind::CancelStorm => vec![
            ("cancels_issued", count(&ev.cancels_issued)),
            ("cancels_acked", count(&ev.cancels_acked)),
            (
                "cancelled_total",
                crate::coordinator::metrics::scrape_value(end_stats, "spa_cancelled_total")
                    .unwrap_or(0.0),
            ),
        ],
        // Degraded-serving evidence: absolute post-drain scrapes (fresh
        // server per run, like `cancelled_total` above).  Zeros on a
        // baseline run without `--page-bytes`/`--grace` — the CI overload
        // gate discriminates the paired rows on exactly that.
        ScenarioKind::Overload => {
            let g = |name: &str| {
                crate::coordinator::metrics::scrape_value(end_stats, name).unwrap_or(0.0)
            };
            vec![
                ("replayed", count(&ev.replayed)),
                ("stale_served", g("spa_stale_served_total")),
                ("degraded_entries", g("spa_degraded_entries_total")),
                ("degraded_exits", g("spa_degraded_exits_total")),
                ("rate_limited", g("spa_rate_limited_total")),
                ("pages_evicted", g("spa_pages_evicted_total")),
                ("drift_debt_peak", g("spa_drift_debt_peak")),
            ]
        }
    };
    SloReport {
        ttft_p99_target_ms: scn.slo.ttft_p99_ms,
        ttft_p99_ms: p99,
        ttft_ok: p99.map(|p| p <= scn.slo.ttft_p99_ms),
        deadline_ms: scn.slo.deadline_ms,
        good,
        total,
        attainment: if total > 0 { Some(good as f64 / total as f64) } else { None },
        goodput_rps: good as f64 / r.measured_s,
        extras,
    }
}

/// Drive one prepared scenario against a serving frontend at `addr`,
/// mirroring `loadgen::drive`'s measurement discipline: warmup-boundary
/// and post-drain stats scrapes, counter differencing, warmup-issued
/// requests excluded — then stamp the scenario tag + SLO block.
fn drive_scenario(
    addr: &str,
    method: &str,
    cfg: &LoadGenConfig,
    scn: &ScenarioConfig,
    plan: Plan,
) -> Result<MethodReport> {
    let t0 = Instant::now();
    let obs: Arc<Mutex<Vec<Obs>>> = Arc::new(Mutex::new(Vec::new()));
    let dropped = Arc::new(AtomicUsize::new(0));
    let ev = Arc::new(Evidence::default());

    let generators = match plan {
        Plan::Chat { sessions, turns } => {
            spawn_chat(addr, cfg, t0, &obs, &ev, sessions, turns)
        }
        Plan::Infill { clients } => spawn_infill(addr, cfg, t0, &obs, &ev, clients),
        Plan::Replay { events } => {
            spawn_replay(addr, cfg, t0, &obs, &dropped, &ev, events)
        }
        Plan::CancelStorm { sessions } => {
            spawn_cancel_storm(addr, cfg, t0, &obs, &ev, sessions)
        }
    };

    sleep_until(t0, cfg.warmup);
    let baseline = match Client::connect(addr).and_then(|mut c| c.stats()) {
        Ok(text) => text,
        Err(e) => {
            crate::warnlog!(
                "scenario",
                "warmup-boundary stats scrape failed ({e:#}); \
                 recorded counters will include warmup work"
            );
            String::new()
        }
    };

    for h in generators {
        let _ = h.join();
    }

    let mut control = Client::connect(addr).context("connect for final scrape")?;
    let drained = control.drain(Duration::from_secs(30))?;
    if !drained {
        crate::warnlog!("scenario", "server did not drain within 30s; final counters may be low");
    }
    let end = control.stats()?;

    let obs = obs.lock().unwrap();
    let mut r = aggregate(method, cfg, &obs, dropped.load(Ordering::SeqCst), &baseline, &end);
    let slo = build_slo(cfg, scn, &r, &obs, &ev, &end);
    r.scenario = Some(scn.kind.name().to_string());
    r.slo = Some(slo);
    Ok(r)
}

/// Run `method` over the stub worker lineup under scenario `scn` — the
/// scenario counterpart of [`super::loadgen::run_stub`], sharing its
/// method-name dispatch (`stub` / `spa` / `spa-adaptive` / `spa-fixed`)
/// and teardown discipline.
pub fn run_stub_scenario(
    method: &str,
    workers: usize,
    cfg: &LoadGenConfig,
    scn: &ScenarioConfig,
    stub: crate::bench::stub::StubConfig,
    policy: PolicyFlags,
) -> Result<MethodReport> {
    let (cfg, plan) = prepare(cfg, scn)?;
    let srv = spawn_stub_server(method, workers, &cfg, stub, policy)?;
    let adaptive_ran = srv.adaptive_ran;
    let report = drive_scenario(&srv.addr, method, &cfg, scn, plan);
    srv.teardown()?;
    report.map(|mut r| {
        r.adaptive = adaptive_ran;
        stamp_prefix_columns(&mut r, policy);
        stamp_paged_columns(&mut r, policy);
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn scenario_names_round_trip() {
        for k in ScenarioKind::ALL {
            assert_eq!(ScenarioKind::from_name(k.name()), Some(k));
        }
        assert_eq!(ScenarioKind::from_name("chaat"), None);
        assert_eq!(ScenarioKind::from_name(""), None);
    }

    #[test]
    fn scenario_config_is_strict() {
        let scn = ScenarioConfig::from_args(ScenarioKind::Chat, &args("")).unwrap();
        assert_eq!(scn.sessions, 4);
        assert_eq!(scn.turns, 4);
        assert!((scn.slo.ttft_p99_ms - 250.0).abs() < 1e-9);
        let scn = ScenarioConfig::from_args(
            ScenarioKind::Mixed,
            &args("--slo-ttft 120 --slo-deadline 900 --sessions 2"),
        )
        .unwrap();
        assert!((scn.slo.ttft_p99_ms - 120.0).abs() < 1e-9);
        assert!((scn.slo.deadline_ms - 900.0).abs() < 1e-9);
        assert_eq!(scn.sessions, 2);
        // Malformed values and misapplied flags error, never record wrong.
        assert!(ScenarioConfig::from_args(ScenarioKind::Chat, &args("--slo-ttft 0")).is_err());
        assert!(ScenarioConfig::from_args(ScenarioKind::Chat, &args("--slo-ttft abc")).is_err());
        assert!(
            ScenarioConfig::from_args(ScenarioKind::Chat, &args("--slo-deadline -5")).is_err()
        );
        assert!(ScenarioConfig::from_args(ScenarioKind::Chat, &args("--sessions 0")).is_err());
        assert!(ScenarioConfig::from_args(ScenarioKind::Infill, &args("--turns 3")).is_err());
        assert!(ScenarioConfig::from_args(ScenarioKind::Chat, &args("--trace t.jsonl")).is_err());
        assert!(
            ScenarioConfig::from_args(ScenarioKind::Mixed, &args("--record-trace t.jsonl"))
                .is_err()
        );
        assert!(
            ScenarioConfig::from_args(ScenarioKind::Trace, &args("--trace t.jsonl")).is_ok()
        );
        // Overload obeys the same applicability rules as the other
        // non-chat shapes, and reads `--qps` as its ramp-peak override.
        let scn = ScenarioConfig::from_args(ScenarioKind::Overload, &args("")).unwrap();
        assert_eq!(scn.peak_qps, None);
        let scn =
            ScenarioConfig::from_args(ScenarioKind::Overload, &args("--qps 300")).unwrap();
        assert_eq!(scn.peak_qps, Some(300.0));
        assert!(
            ScenarioConfig::from_args(ScenarioKind::Overload, &args("--turns 3")).is_err()
        );
        assert!(ScenarioConfig::from_args(
            ScenarioKind::Overload,
            &args("--trace t.jsonl")
        )
        .is_err());
    }

    #[test]
    fn overload_trace_ramps_and_is_seed_deterministic() {
        let cfg = LoadGenConfig {
            warmup: Duration::from_millis(200),
            duration: Duration::from_secs(2),
            seed: 7,
            ..LoadGenConfig::default()
        };
        let a = synth_overload_trace(&cfg, 200.0);
        assert!(!a.is_empty());
        assert_eq!(a, synth_overload_trace(&cfg, 200.0), "same seed → same schedule");
        let other = LoadGenConfig { seed: 8, ..cfg.clone() };
        assert_ne!(a, synth_overload_trace(&other, 200.0));
        assert!(a.windows(2).all(|w| w[0].at_ms < w[1].at_ms), "strictly increasing");
        // The ramp: the second half of the window sees more arrivals than
        // the first (rate climbs from peak/10 toward peak).
        let total_ms = (cfg.warmup + cfg.duration).as_secs_f64() * 1e3;
        let early = a.iter().filter(|e| e.at_ms < total_ms / 2.0).count();
        let late = a.len() - early;
        assert!(late > early, "ramp must accelerate: {early} early vs {late} late");
        // Short-chat arrivals carry one of the stable session keys; the
        // long-doc share carries none.
        let keyed = a.iter().filter(|e| e.session.is_some()).count();
        assert!(keyed > 0 && keyed < a.len());
        let distinct: std::collections::HashSet<&String> =
            a.iter().filter_map(|e| e.session.as_ref()).collect();
        assert!(distinct.len() <= OVERLOAD_SESSIONS);
    }

    #[test]
    fn infill_spec_is_non_contiguous_and_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..50 {
            let (ta, oa) = infill_spec(&mut a);
            let (tb, ob) = infill_spec(&mut b);
            assert_eq!((&ta, &oa), (&tb, &ob), "same seed, same spec");
            assert!(oa.windows(2).all(|w| w[0] < w[1]), "ascending unique: {oa:?}");
            assert!(*oa.last().unwrap() < ta.len(), "offsets in range");
            // The guaranteed hole: 0 and 2 masked, 1 fixed.
            assert!(oa.contains(&0) && !oa.contains(&1) && oa.contains(&2), "{oa:?}");
        }
        let (tc, oc) = infill_spec(&mut Rng::new(43));
        let (ta, oa) = infill_spec(&mut Rng::new(42));
        assert!(
            (ta, oa) != (tc, oc),
            "different seeds should draw different specs"
        );
    }

    #[test]
    fn synth_traces_are_seed_deterministic() {
        let cfg = LoadGenConfig {
            warmup: Duration::from_millis(200),
            duration: Duration::from_secs(2),
            seed: 7,
            ..LoadGenConfig::default()
        };
        let a = synth_mixed_trace(&cfg, 25.0);
        let b = synth_mixed_trace(&cfg, 25.0);
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed → identical schedule");
        let other = LoadGenConfig { seed: 8, ..cfg.clone() };
        assert_ne!(a, synth_mixed_trace(&other, 25.0), "seed changes the schedule");
        let a = synth_bursty_trace(&cfg);
        assert!(!a.is_empty());
        assert_eq!(a, synth_bursty_trace(&cfg));
        assert_ne!(a, synth_bursty_trace(&other));
        // Arrival times are non-decreasing within a burst-spread trace.
        assert!(a.windows(2).all(|w| w[0].at_ms <= w[1].at_ms), "sorted arrivals");
    }

    #[test]
    fn trace_file_round_trips_and_reads_strictly() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("spa_trace_unit_{}.jsonl", std::process::id()));
        let cfg = LoadGenConfig {
            warmup: Duration::from_millis(100),
            duration: Duration::from_secs(1),
            seed: 5,
            ..LoadGenConfig::default()
        };
        let events = synth_bursty_trace(&cfg);
        write_trace(&path, &events).unwrap();
        assert_eq!(read_trace(&path).unwrap(), events, "record → replay is lossless");

        // Out-of-order events come back sorted by arrival time.
        std::fs::write(
            &path,
            "{\"at_ms\": 50, \"prompt\": \"b\", \"gen_len\": 4}\n\
             {\"at_ms\": 10, \"prompt\": \"a\", \"gen_len\": 4}\n",
        )
        .unwrap();
        let sorted = read_trace(&path).unwrap();
        assert_eq!(sorted[0].prompt, "a");
        assert_eq!(sorted[1].prompt, "b");

        // Session keys round-trip when present and stay absent otherwise —
        // pre-session trace files keep replaying unchanged.
        let with_session = vec![
            TraceEvent { at_ms: 1.0, prompt: "a".into(), gen_len: 4, session: None },
            TraceEvent {
                at_ms: 2.0,
                prompt: "b".into(),
                gen_len: 4,
                session: Some("chat-7-0".into()),
            },
        ];
        write_trace(&path, &with_session).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert!(!lines.next().unwrap().contains("session"), "no key when absent");
        assert!(lines.next().unwrap().contains("\"session\""));
        assert_eq!(read_trace(&path).unwrap(), with_session, "session round-trips");

        // Strictness: malformed lines error with a location, not skip.
        for bad in [
            "not json\n",
            "{\"prompt\": \"a\", \"gen_len\": 4}\n",
            "{\"at_ms\": -1, \"prompt\": \"a\", \"gen_len\": 4}\n",
            "{\"at_ms\": 1, \"gen_len\": 4}\n",
            "{\"at_ms\": 1, \"prompt\": \"a\"}\n",
            "{\"at_ms\": 1, \"prompt\": \"a\", \"gen_len\": 0}\n",
        ] {
            std::fs::write(&path, bad).unwrap();
            assert!(read_trace(&path).is_err(), "must reject: {bad}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn slo_json_guards_non_finite_and_carries_schema() {
        let s = SloReport {
            ttft_p99_target_ms: 250.0,
            ttft_p99_ms: None,
            ttft_ok: None,
            deadline_ms: 1000.0,
            good: 0,
            total: 0,
            attainment: None,
            goodput_rps: f64::NAN,
            extras: vec![("turns", 0.0)],
        };
        let j = slo_json(&s);
        let back = parse(&j.to_string()).unwrap();
        assert_eq!(back.get("schema").and_then(|x| x.as_f64()), Some(SLO_SCHEMA));
        assert_eq!(back.get("ttft_p99_ms"), Some(&Json::Null));
        assert_eq!(back.get("ttft_ok"), Some(&Json::Null));
        assert_eq!(back.get("deadline_attainment"), Some(&Json::Null));
        assert_eq!(back.get("goodput_rps"), Some(&Json::Null));
        assert_eq!(back.get("turns").and_then(|x| x.as_f64()), Some(0.0));
    }

    #[test]
    fn build_slo_counts_goodput_under_deadline() {
        let cfg = LoadGenConfig {
            warmup: Duration::from_secs(1),
            ..LoadGenConfig::default()
        };
        let mk = |issued_s: f64, latency_ms: f64, ttft_ms: f64, error: bool| Obs {
            issued_s,
            done_s: issued_s + latency_ms / 1e3,
            wall_ms: latency_ms,
            ttft_ms,
            latency_ms,
            decoded: 8.0,
            error,
        };
        let obs = vec![
            mk(0.5, 100.0, 10.0, false), // warmup: excluded
            mk(1.5, 100.0, 10.0, false), // good
            mk(2.0, 400.0, 20.0, false), // good
            mk(2.5, 5000.0, 30.0, false), // over deadline: completes, not good
            mk(2.6, 100.0, 10.0, true),  // error: excluded entirely
        ];
        let r = aggregate("stub", &cfg, &obs, 0, "", "");
        let scn = ScenarioConfig {
            kind: ScenarioKind::Chat,
            slo: SloTargets { ttft_p99_ms: 25.0, deadline_ms: 1000.0 },
            sessions: 1,
            turns: 4,
            trace: None,
            record_trace: None,
            peak_qps: None,
        };
        let ev = Evidence::default();
        ev.turns.fetch_add(3, Ordering::SeqCst);
        let s = build_slo(&cfg, &scn, &r, &obs, &ev, "");
        assert_eq!((s.total, s.good), (3, 2));
        assert!((s.attainment.unwrap() - 2.0 / 3.0).abs() < 1e-9);
        // p99 of {10, 20, 30} is 30 > 25 → the TTFT SLO is missed.
        assert_eq!(s.ttft_ok, Some(false));
        assert!(s.goodput_rps > 0.0);
        assert_eq!(s.extras, vec![("turns", 3.0)]);
        // No completions at all → explicit "unmeasurable", not zeros.
        let r0 = aggregate("stub", &cfg, &[], 0, "", "");
        let s0 = build_slo(&cfg, &scn, &r0, &[], &ev, "");
        assert_eq!((s0.total, s0.good), (0, 0));
        assert_eq!(s0.ttft_ok, None);
        assert_eq!(s0.attainment, None);
    }
}
