//! SPA-Cache: Singular Proxies for Adaptive Caching in Diffusion Language
//! Models — a three-layer Rust + JAX + Pallas reproduction.
//!
//! Layering (see DESIGN.md):
//! * L1/L2 live in `python/compile/` and run only at build time, producing
//!   AOT HLO-text executables under `artifacts/`.
//! * [`runtime`] loads and executes those artifacts via PJRT (the `xla`
//!   crate) — python is never on the request path.
//! * [`coordinator`] is the serving system: router/batcher/scheduler, the
//!   cache-policy subsystem (SPA-Cache + every baseline behind one
//!   `CachePolicy` trait), decode policies, metrics, and a TCP server.
//! * [`analysis`] regenerates the paper's figures from probe artifacts.
//! * [`bench`] is a criterion-substitute harness for the paper tables,
//!   plus the serving load generator behind `spa-cache bench-serve`.
//! * [`util`] holds the from-scratch substrates (json/cli/rng/stats/
//!   threadpool/proptest) required by the offline environment.

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod model;
pub mod runtime;
pub mod util;
