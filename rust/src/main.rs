//! SPA-Cache CLI: serve | bench-serve | generate | analyze | selftest | list
//!
//! Examples:
//!   spa-cache list
//!   spa-cache generate --model llada_s --method spa --task gsm8k_s --samples 4
//!   spa-cache serve --addr 127.0.0.1:7377 --model llada_s --method spa --workers 4
//!   spa-cache bench-serve --workers 2 --qps 50 --duration 5s --methods vanilla,spa
//!   spa-cache bench-serve --workers 2 --clients 8 --duration 10s   (closed loop)
//!   spa-cache bench-serve --workers 2 --pipeline 8 --duration 10s  (one v2 session)
//!   spa-cache bench-serve --stub --pipeline 8 --duration 2s        (no artifacts)
//!   spa-cache bench-serve --stub --scenario chat --duration 2s     (SLO scenario)
//!   spa-cache analyze --model llada_s --steps 12
//!   spa-cache selftest

use anyhow::Result;

use spa_cache::coordinator::batcher::BatcherConfig;
use spa_cache::coordinator::cache::{Method, MethodSpec, PolicyFlags};
use spa_cache::coordinator::decode::{Sampler, UnmaskMode};
use spa_cache::coordinator::group::{pack_group, run_group};
use spa_cache::coordinator::router::Router;
use spa_cache::coordinator::scheduler::Worker;
use spa_cache::coordinator::server;
use spa_cache::model::tasks::{make_sample, Task, extract_answer, ALL_TASKS};
use spa_cache::model::tokenizer::Tokenizer;
use spa_cache::runtime::engine::Engine;
use spa_cache::runtime::manifest::Manifest;
use spa_cache::util::cli::Args;
use spa_cache::util::rng::Rng;

fn main() -> Result<()> {
    spa_cache::util::log::init();
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "list" => list(&args),
        "generate" => generate(&args),
        "serve" => serve(&args),
        "bench-serve" => bench_serve(&args),
        "analyze" => analyze(&args),
        "selftest" => selftest(&args),
        _ => {
            eprintln!(
                "usage: spa-cache <list|generate|serve|bench-serve|analyze|selftest> \
                 [--model llada_s] [--method vanilla|spa|dllm_cache|fast_dllm|dkv_cache|d2_cache|elastic_cache|multistep] \
                 [--task gsm8k_s] [--samples N] [--addr host:port] [--workers N] [--threshold 0.9]\n\
                 policy: [--partial-refresh on|off] [--refresh-interval N] \
                 [--adaptive on|off] [--row-refresh N] [--refit-interval N] \
                 [--prefix-cache on|off] [--prefix-mem BYTES] \
                 [--page-bytes BYTES] [--grace N]\n\
                 serve: [--max-line BYTES] [--conn-threads N]\n\
                 bench-serve: [--methods vanilla,spa] [--qps 8 | --clients N | --pipeline D] \
                 [--duration 5s] [--warmup 1s] [--tasks gsm8k_s,mmlu_s] [--gen-len 32 | 16:64] \
                 [--out BENCH_serving.json] [--stub]\n\
                 (--stub: stub workers, no artifacts needed; stub methods \
                 stub|spa|spa-adaptive|spa-fixed run the real policy loop)\n\
                 scenarios (--stub only): \
                 [--scenario chat|infill|mixed|trace|cancel-storm|overload] \
                 [--slo-ttft MS] [--slo-deadline MS] [--sessions N] [--turns N] \
                 [--trace FILE] [--record-trace FILE] \
                 (overload: --qps sets the ramp peak, default 400)"
            );
            Ok(())
        }
    }
}

fn list(args: &Args) -> Result<()> {
    let engine = engine(args)?;
    println!("models:");
    for (name, m) in &engine.manifest.models {
        println!(
            "  {name}: d={} L={} heads={}/{} vocab={} (eval: {:?})",
            m.arch.d_model, m.arch.n_layers, m.arch.n_heads, m.arch.n_kv_heads,
            m.arch.vocab_size, m.eval_accuracy
        );
    }
    println!("\nvariants ({}):", engine.manifest.variants.len());
    for (name, v) in &engine.manifest.variants {
        println!("  {name} [{}] id={} r={} k={:?}", v.kind, v.identifier, v.rank, v.k_per_layer);
    }
    println!("\ntasks:");
    for (name, t) in &engine.manifest.tasks {
        println!("  {name} -> {} (gen {}, block {})", t.paper_name, t.gen_len, t.block_len);
    }
    Ok(())
}

fn engine(args: &Args) -> Result<Engine> {
    match args.get("artifacts") {
        Some(dir) => Engine::new(dir),
        None => Engine::from_default_artifacts(),
    }
}

fn sampler(args: &Args) -> Sampler {
    let threshold = args.f64_or("threshold", 0.0);
    let mode = if args.flag("block") {
        UnmaskMode::BlockParallel { threshold: if threshold > 0.0 { threshold } else { 0.9 } }
    } else if threshold > 0.0 {
        UnmaskMode::Parallel { threshold }
    } else {
        UnmaskMode::Sequential
    };
    let mut s = Sampler::greedy(mode);
    s.temperature = args.f64_or("temperature", 0.0);
    s
}

fn generate(args: &Args) -> Result<()> {
    let engine = engine(args)?;
    let model = args.str_or("model", "llada_s");
    let task = Task::from_name(&args.str_or("task", "gsm8k_s"))
        .ok_or_else(|| anyhow::anyhow!("unknown task"))?;
    let method_name = args.str_or("method", "spa");
    let samples = args.usize_or("samples", 4);
    let seed = args.u64_or("seed", 1);

    let spec = MethodSpec::by_name(&method_name, task.block_len())?;
    let mut method = Method::new(&engine, &model, spec)?;
    let (b, n, _) = method.geometry();
    let tok = Tokenizer::from_manifest(&engine.manifest.charset);
    let mut rng = Rng::new(seed);
    let mut sampler = sampler(args);
    if method_name == "fast_dllm" {
        sampler.mode = UnmaskMode::BlockParallel { threshold: args.f64_or("threshold", 0.9) };
    }

    let mut correct = 0usize;
    let mut total = 0usize;
    let mut done = 0usize;
    while done < samples {
        let batch: Vec<_> =
            (0..b.min(samples - done)).map(|_| make_sample(task, &mut rng, &tok, n)).collect();
        let real = batch.len();
        let (mut tokens, mut slots) = pack_group(&batch, b, n, task.block_len());
        let out = run_group(&engine, &mut method, &mut sampler, &mut tokens, &mut slots, 4 * n)?;
        for (i, s) in batch.iter().enumerate() {
            let row = &out.tokens[i * n..(i + 1) * n];
            let answer = extract_answer(&tok, row, s.prompt_len);
            let hit = answer == s.answer;
            correct += hit as usize;
            total += 1;
            println!(
                "[{}] Q: {:?}\n    -> {:?} (truth {:?}) {}",
                s.task.name(),
                tok.decode(&s.tokens[..s.prompt_len]),
                answer,
                s.answer,
                if hit { "✓" } else { "✗" }
            );
        }
        println!(
            "group: {} steps, {:.1} tok/s, ttft {:.1} ms",
            out.steps,
            out.tps(),
            out.ttft_ms[0]
        );
        done += real;
    }
    println!("\naccuracy: {}/{} = {:.1}%", correct, total, 100.0 * correct as f64 / total as f64);
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    // Parse the manifest once; each worker thread clones it into its own
    // engine (PJRT handles are !Send, so engines are built per-thread).
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let manifest = Manifest::load(&artifacts)?;
    let seq_len = manifest.seq_len;
    let charset = manifest.charset.clone();

    let model = args.str_or("model", "llada_s");
    let method_name = args.str_or("method", "spa");
    let addr = args.str_or("addr", "127.0.0.1:7377");
    let workers = args.count_or("workers", 1);
    let block_k = args.usize_or("block-k", 16);
    // Policy flags: `--partial-refresh off` restores the blanket
    // admission invalidate; `--refresh-interval N` overrides the method's
    // scheduled full-refresh cadence; `--adaptive on` attaches the online
    // budget controller (drift-driven ρ refits + tier selection over the
    // registry's spa variant family).  Strict — an explicitly supplied
    // but malformed *or inapplicable* value must not silently serve the
    // default policy (same validation as the bench front-ends).
    let policy = PolicyFlags::from_args(args)?;
    {
        let spec = MethodSpec::by_name(&method_name, block_k)?;
        spa_cache::bench::loadgen::validate_policy_flags(
            policy,
            args.get("partial-refresh").is_some(),
            std::slice::from_ref(&spec),
        )?;
    }
    let mut sam = sampler(args);
    if method_name == "fast_dllm" {
        sam.mode = UnmaskMode::BlockParallel { threshold: args.f64_or("threshold", 0.9) };
    } else if args.get("threshold").is_none() {
        sam.mode = UnmaskMode::Parallel { threshold: 0.9 };
    }
    let batcher = BatcherConfig::default();

    // Spawn blocks until every worker's engine is constructed, so a bad
    // model/method/artifact path fails here instead of serving dead workers.
    let (router, handles) = Router::spawn(workers, move |id| {
        let engine = Engine::from_manifest(manifest.clone())?;
        let spec = MethodSpec::by_name(&method_name, block_k)?
            .with_refresh_interval(policy.refresh_interval);
        let mut method = Method::new(&engine, &model, spec)?;
        method.configure(&engine, &policy)?;
        Ok(Worker::new(id, Box::new(engine), method, sam.clone(), batcher.clone(), 4 * seq_len))
    })?;

    // Frontend knobs: request-line cap + concurrent connection handlers.
    let server_cfg = server::ServerConfig {
        conn_threads: args
            .strict_count("conn-threads")?
            .unwrap_or(server::DEFAULT_CONN_THREADS),
        max_line: args
            .strict_count("max-line")?
            .unwrap_or(server::DEFAULT_MAX_LINE),
        max_inflight_per_conn: args
            .strict_count("max-session-inflight")?
            .unwrap_or(server::DEFAULT_SESSION_INFLIGHT),
    };
    let listener = std::net::TcpListener::bind(&addr)
        .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
    server::serve_listener(listener, seq_len, &charset, router, server_cfg)?;
    for h in handles {
        match h.join() {
            Ok(r) => r?,
            Err(_) => anyhow::bail!("worker thread panicked"),
        }
    }
    Ok(())
}

/// Drive the multi-worker serving path under generated load and append a
/// trajectory entry to `BENCH_serving.json` (DESIGN.md §10).  Skips
/// gracefully (exit 0, with a message) when artifacts or the PJRT runtime
/// are unavailable, mirroring the artifact-gated tests.
fn bench_serve(args: &Args) -> Result<()> {
    use spa_cache::bench::loadgen::{self, LoadGenConfig};
    use spa_cache::bench::scenario;

    // --stub: artifact-free smoke over stub session workers — the whole
    // TCP → router → worker pipeline minus the device execution.  CI uses
    // this so the serving trajectory populates on every run, not only
    // where artifacts exist.  The `spa`/`spa-adaptive`/`spa-fixed` stub
    // methods run the *real* cache-policy decision loop (and adaptive
    // budget controller) over a stubbed engine, so the policy flags apply
    // here too; plain `stub` ignores them and rejects them explicitly.
    if args.flag("stub") {
        let workers = args.strict_count("workers")?.unwrap_or(2);
        let cfg = LoadGenConfig::from_args(args)?;
        let policy = PolicyFlags::from_args(args)?;
        let methods: Vec<String> = args
            .str_or("methods", "stub")
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        // Validation mirrors the engine path: pseudo-specs for the
        // policy-stub methods, nothing for the plain session stub — so
        // policy flags with a stub-only lineup still error loudly.
        let pseudo_specs: Vec<MethodSpec> = methods
            .iter()
            .filter(|m| m.starts_with("spa"))
            .map(|_| MethodSpec::Spa { variant: "spa_default".into(), refresh_interval: 0 })
            .collect();
        loadgen::validate_policy_flags(
            policy,
            args.get("partial-refresh").is_some(),
            &pseudo_specs,
        )?;
        // --scenario: drive a production-shaped workload (bench::scenario)
        // instead of the plain load shapes, and stamp each report with a
        // scenario tag + SLO block.
        let scenario = match args.get("scenario") {
            Some(name) => Some(scenario::ScenarioKind::from_name(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown --scenario '{name}' (valid: {})",
                    scenario::ScenarioKind::ALL
                        .iter()
                        .map(|k| k.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?),
            None => None,
        };
        let mut reports = Vec::new();
        if let Some(kind) = scenario {
            let scn = scenario::ScenarioConfig::from_args(kind, args)?;
            for m in &methods {
                reports.push(scenario::run_stub_scenario(
                    m,
                    workers,
                    &cfg,
                    &scn,
                    spa_cache::bench::stub::StubConfig::default(),
                    policy,
                )?);
            }
        } else {
            for m in &methods {
                reports.push(loadgen::run_stub(
                    m,
                    workers,
                    &cfg,
                    spa_cache::bench::stub::StubConfig::default(),
                    policy,
                )?);
            }
        }
        loadgen::print_reports(&reports);
        scenario::print_slo(&reports);
        let out = loadgen::out_path(args);
        loadgen::append_trajectory(
            &out,
            loadgen::config_json(&cfg, workers, "stub", policy),
            &reports,
        )?;
        println!(
            "bench-serve: appended {} stub row(s) to {}",
            reports.len(),
            out.display()
        );
        return Ok(());
    }
    anyhow::ensure!(
        args.get("scenario").is_none(),
        "--scenario requires --stub (scenarios run artifact-free over the stub workers)"
    );

    // Gate on the resolved dir, so an explicit --artifacts is honoured
    // (shared with examples/bench_serve.rs — the two must not drift).
    let artifacts = match loadgen::resolve_artifacts(args) {
        Ok(dir) => dir,
        Err(why) => {
            println!("bench-serve: SKIP ({why})");
            return Ok(());
        }
    };
    let manifest = Manifest::load(&artifacts)?;
    let seq_len = manifest.seq_len;
    let charset = manifest.charset.clone();

    let model = args.str_or("model", "llada_s");
    // Strict: worker count and policy flags are recorded in the
    // trajectory config — a typo must error, never record a wrong entry.
    let workers = args.strict_count("workers")?.unwrap_or(2);
    let block_k = args.usize_or("block-k", 16);
    let threshold = args.f64_or("threshold", 0.9);
    let policy = PolicyFlags::from_args(args)?;
    let methods: Vec<String> = args
        .str_or("methods", "vanilla,spa")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    // A typo'd method must error here, not surface as a per-method SKIP
    // (SKIP is reserved for engine/PJRT unavailability — a CI smoke must
    // never go green having measured zero methods by typo).
    let mut specs = Vec::new();
    for m in &methods {
        specs.push(
            MethodSpec::by_name(m, block_k)
                .map_err(|e| anyhow::anyhow!("--methods '{m}': {e:#}"))?,
        );
    }
    // Policy flags must be applicable to at least one selected method —
    // the recorded config must never claim gates the run ignored.
    loadgen::validate_policy_flags(policy, args.get("partial-refresh").is_some(), &specs)?;

    // --clients N selects the closed loop; otherwise open loop at --qps
    // (shared flag parsing with examples/bench_serve.rs).
    let cfg = LoadGenConfig::from_args(args)?;

    let mut reports = Vec::new();
    for (method_name, spec) in methods.iter().zip(&specs) {
        let spawned = loadgen::run_method(
            method_name,
            workers,
            seq_len,
            &charset,
            &cfg,
            loadgen::worker_factory(
                manifest.clone(),
                model.clone(),
                method_name.clone(),
                block_k,
                threshold,
                policy,
            ),
        );
        match spawned {
            Ok(mut r) => {
                // The adaptive gate is a capability: it attaches only to
                // spa-kind methods, and the row records what ran.
                r.adaptive = loadgen::adaptive_applies(policy, spec);
                reports.push(r);
            }
            Err(e) => println!("bench-serve: SKIP method {method_name}: {e:#}"),
        }
    }
    if reports.is_empty() {
        println!("bench-serve: no method ran (engine/PJRT unavailable?) — nothing recorded");
        return Ok(());
    }
    loadgen::print_reports(&reports);
    let out = loadgen::out_path(args);
    loadgen::append_trajectory(
        &out,
        loadgen::config_json(&cfg, workers, &model, policy),
        &reports,
    )?;
    println!(
        "bench-serve: appended {} method row(s) to {}",
        reports.len(),
        out.display()
    );
    Ok(())
}

fn analyze(args: &Args) -> Result<()> {
    use spa_cache::analysis::drift::{run_probe, CHANNELS};
    use spa_cache::model::schedule::fit_piecewise_gaussian;
    let engine = engine(args)?;
    let model = args.str_or("model", "llada_s");
    let steps = args.usize_or("steps", 12);
    let tok = Tokenizer::from_manifest(&engine.manifest.charset);
    let mut rng = Rng::new(args.u64_or("seed", 7));
    let (b, n) = (engine.manifest.batch, engine.manifest.seq_len);
    let samples: Vec<_> = (0..b)
        .map(|i| make_sample(ALL_TASKS[i % ALL_TASKS.len()], &mut rng, &tok, n))
        .collect();
    let (mut tokens, mut slots) = pack_group(&samples, b, n, 16);
    let profile = run_probe(&engine, &model, &mut tokens, &mut slots, steps, 0.6)?;
    println!("mean adjacent-step similarity per layer:");
    println!("layer  {}", CHANNELS.join("      "));
    for (i, row) in profile.mean_sims().iter().enumerate() {
        println!(
            "{:>5}  {:.4}  {:.4}  {:.4}  {:.4}  {:.4}",
            i + 1, row[0], row[1], row[2], row[3], row[4]
        );
    }
    let drift = profile.mean_drift();
    println!("\ndrift fraction (out-sim < 0.95) per layer: {drift:?}");
    let fit = fit_piecewise_gaussian(&drift, 0.5);
    println!("fitted Eq.5 schedule: {fit:?}");
    Ok(())
}

fn selftest(args: &Args) -> Result<()> {
    let engine = engine(args)?;
    let model = args.str_or("model", "llada_s");
    println!("selftest: vanilla forward + spa decode on {model}");
    let tok = Tokenizer::from_manifest(&engine.manifest.charset);
    let mut rng = Rng::new(0);
    let (b, n, _) = (engine.manifest.batch, engine.manifest.seq_len, 0);
    let samples: Vec<_> =
        (0..b).map(|_| make_sample(Task::Gsm8kS, &mut rng, &tok, n)).collect();
    for m in ["vanilla", "spa"] {
        let spec = MethodSpec::by_name(m, 16)?;
        let mut method = Method::new(&engine, &model, spec)?;
        let mut sampler = Sampler::greedy(UnmaskMode::Parallel { threshold: 0.6 });
        let (mut tokens, mut slots) = pack_group(&samples, b, n, 16);
        let out = run_group(&engine, &mut method, &mut sampler, &mut tokens, &mut slots, 4 * n)?;
        println!("  {m}: {} steps, {:.1} tok/s", out.steps, out.tps());
    }
    println!("selftest OK");
    Ok(())
}
