//! Analysis toolkit regenerating the paper's figures from the `probe`
//! artifact: adjacent-step similarities (Fig 1/7), layer drift profiles and
//! Eq. 5 fits (Fig 2/6, Table 6), and anisotropy densities (Fig 5).

pub mod anisotropy;
pub mod drift;
