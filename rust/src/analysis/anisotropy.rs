//! Anisotropy analysis (paper Fig. 5 + Appendix B).
//!
//! Compares the cross-token cosine-similarity distribution of Value states
//! against attention outputs.  Isotropic features (values) cluster near 0;
//! the attention output collapses into a narrow cone (similarities → 1),
//! which masks per-token drift — the paper's explanation for why the
//! attn-output identifier fails (Table 1).

use crate::util::rng::Rng;
use crate::util::stats::Histogram;

/// Cross-token cosine-similarity histogram for one feature matrix
/// `[tokens, dim]` (row-major), sampling `pairs` random i≠j pairs.
pub fn pair_similarity_hist(
    feats: &[f32],
    tokens: usize,
    dim: usize,
    pairs: usize,
    rng: &mut Rng,
) -> Histogram {
    assert_eq!(feats.len(), tokens * dim);
    let mut h = Histogram::new(-1.0, 1.0000001, 40);
    let norms: Vec<f64> = (0..tokens)
        .map(|t| {
            feats[t * dim..(t + 1) * dim]
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt()
        })
        .collect();
    for _ in 0..pairs {
        let i = rng.range(0, tokens);
        let mut j = rng.range(0, tokens);
        if i == j {
            j = (j + 1) % tokens;
        }
        if norms[i] < 1e-9 || norms[j] < 1e-9 {
            continue;
        }
        let dot: f64 = (0..dim)
            .map(|d| feats[i * dim + d] as f64 * feats[j * dim + d] as f64)
            .sum();
        h.push(dot / (norms[i] * norms[j]));
    }
    h
}

/// Mean of a histogram interpreted over its bin centres.
pub fn hist_mean(h: &Histogram) -> f64 {
    let nb = h.bins.len();
    let w = (h.hi - h.lo) / nb as f64;
    let total: u64 = h.bins.iter().sum();
    if total == 0 {
        return 0.0;
    }
    h.bins
        .iter()
        .enumerate()
        .map(|(i, &c)| (h.lo + (i as f64 + 0.5) * w) * c as f64)
        .sum::<f64>()
        / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orthogonal_features_center_near_zero() {
        // random gaussian features are near-orthogonal in high dim
        let mut rng = Rng::new(1);
        let (t, d) = (64, 128);
        let feats: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let h = pair_similarity_hist(&feats, t, d, 2000, &mut rng);
        assert!(hist_mean(&h).abs() < 0.1);
    }

    #[test]
    fn common_direction_shifts_mean_up() {
        // v_i = c + s_i with ||c|| >> ||s_i||  (paper Eq. 39/40)
        let mut rng = Rng::new(2);
        let (t, d) = (64, 128);
        let common: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 3.0).collect();
        let mut feats = vec![0.0f32; t * d];
        for i in 0..t {
            for j in 0..d {
                feats[i * d + j] = common[j] + rng.normal() as f32 * 0.3;
            }
        }
        let h = pair_similarity_hist(&feats, t, d, 2000, &mut rng);
        assert!(hist_mean(&h) > 0.8, "mean {}", hist_mean(&h));
    }
}
