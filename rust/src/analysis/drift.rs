//! Probe-driven drift analysis (paper Figures 1, 2, 6, 7 and Table 6).
//!
//! Drives the `<model>__probe` variant through a decode, collecting the
//! in-graph adjacent-step cosine similarities for five features per layer:
//! input, value, singular proxy, attention output, layer output.

use anyhow::Result;
use xla::Literal;

use crate::coordinator::decode::{Sampler, UnmaskMode};
use crate::coordinator::request::SlotState;
use crate::model::tokenizer::MASK;
use crate::runtime::engine::Engine;
use crate::runtime::tensor::{literal_i32, literal_zeros_f32, to_f32_vec};

/// Similarity channels in the probe's `sims` output, in order.
pub const CHANNELS: [&str; 5] = ["input", "value", "proxy", "attn_out", "output"];
pub const TAU: f64 = 0.95; // paper's drift threshold

/// Per-step similarity record: `sims[layer][channel]` = mean over tokens,
/// plus the raw per-token output-similarity for drift fractions.
#[derive(Debug, Clone)]
pub struct StepSims {
    pub mean: Vec<[f64; 5]>,          // [L][channel]
    pub drift_fraction: Vec<f64>,     // [L]: fraction of tokens with out-sim < τ
    pub per_token_output: Vec<Vec<f32>>, // [L][B*N]
}

/// Full result of a probe decode.
#[derive(Debug)]
pub struct DriftProfile {
    pub model: String,
    pub steps: Vec<StepSims>,
    pub n_layers: usize,
}

impl DriftProfile {
    /// Average drift fraction per layer over steps ≥ 1 (paper Fig. 2).
    pub fn mean_drift(&self) -> Vec<f64> {
        let l = self.n_layers;
        let mut acc = vec![0.0; l];
        let mut cnt = 0usize;
        for s in self.steps.iter().skip(1) {
            for (i, d) in s.drift_fraction.iter().enumerate() {
                acc[i] += d;
            }
            cnt += 1;
        }
        acc.iter().map(|x| x / cnt.max(1) as f64).collect()
    }

    /// Mean similarity per (layer, channel) over steps ≥ 1 (Fig. 1/7).
    pub fn mean_sims(&self) -> Vec<[f64; 5]> {
        let l = self.n_layers;
        let mut acc = vec![[0.0; 5]; l];
        let mut cnt = 0usize;
        for s in self.steps.iter().skip(1) {
            for i in 0..l {
                for c in 0..5 {
                    acc[i][c] += s.mean[i][c];
                }
            }
            cnt += 1;
        }
        for row in &mut acc {
            for c in row.iter_mut() {
                *c /= cnt.max(1) as f64;
            }
        }
        acc
    }
}

/// Run a probe decode and collect similarities.
///
/// `tokens` is a packed `[B, N]` buffer (see `group::pack_group`); decoding
/// uses the sequential greedy sampler so every step has exactly B commits.
pub fn run_probe(
    engine: &Engine,
    model: &str,
    tokens: &mut Vec<i32>,
    slots: &mut Vec<SlotState>,
    max_steps: usize,
    threshold: f64,
) -> Result<DriftProfile> {
    let variant = engine.load_variant(&format!("{model}__probe"))?;
    let vinfo = &variant.info;
    let (b, n) = (vinfo.batch, vinfo.seq_len);
    let l = engine.manifest.model(model)?.arch.n_layers;
    let vocab = vinfo.outputs[0].shape[2];

    // Zero-initialised records for step 0.
    let mut records: Vec<Literal> = vinfo
        .inputs
        .iter()
        .filter(|i| i.name != "tokens")
        .map(|i| literal_zeros_f32(&i.shape))
        .collect::<Result<_>>()?;

    let mut sampler = Sampler::greedy(UnmaskMode::Parallel { threshold });
    let mut steps = Vec::new();
    for _ in 0..max_steps {
        if !tokens.iter().any(|&t| t == MASK) {
            break;
        }
        let tok_lit = literal_i32(&[b, n], tokens)?;
        let mut inputs: Vec<&Literal> = vec![&tok_lit];
        inputs.extend(records.iter());
        let mut outs = engine.run(&variant, &inputs)?;
        // outputs: [logits, xin, val, prox, ao, out, sims]
        let sims_lit = outs.pop().unwrap();
        let logits = to_f32_vec(&outs[0])?;
        records = outs.drain(1..).collect();

        let sims = to_f32_vec(&sims_lit)?; // [L,B,N,5]
        let mut mean = vec![[0.0f64; 5]; l];
        let mut drift = vec![0.0f64; l];
        let mut per_tok = vec![vec![0.0f32; b * n]; l];
        for li in 0..l {
            for p in 0..b * n {
                for c in 0..5 {
                    let v = sims[(li * b * n + p) * 5 + c] as f64;
                    mean[li][c] += v;
                }
                let out_sim = sims[(li * b * n + p) * 5 + 4];
                per_tok[li][p] = out_sim;
                if (out_sim as f64) < TAU {
                    drift[li] += 1.0;
                }
            }
            for c in 0..5 {
                mean[li][c] /= (b * n) as f64;
            }
            drift[li] /= (b * n) as f64;
        }
        steps.push(StepSims { mean, drift_fraction: drift, per_token_output: per_tok });

        sampler.unmask(tokens, &logits, b, n, vocab, slots);
    }
    Ok(DriftProfile { model: model.to_string(), steps, n_layers: l })
}
