//! Model-side metadata: tokenizer, budget schedule (Eq. 5) and the
//! synthetic task suites. Mirrors of the python build-time modules; the
//! golden tests pin both sides together.

pub mod schedule;
pub mod tasks;
pub mod tokenizer;
