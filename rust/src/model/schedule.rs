//! Adaptive budget allocation (paper Eq. 5) — mirror of
//! `python/compile/schedule.py`, cross-checked against the manifest goldens.

/// Parameters of the piecewise-Gaussian update-ratio curve (paper Table 6).
#[derive(Debug, Clone, PartialEq)]
pub struct RhoSchedule {
    pub l_p: usize, // peak layer, 1-indexed
    pub rho_p: f64,
    pub rho_1: f64,
    pub rho_l: f64,
}

impl RhoSchedule {
    pub fn uniform(rho: f64) -> RhoSchedule {
        RhoSchedule { l_p: 1, rho_p: rho, rho_1: rho, rho_l: rho }
    }

    /// Update ratio for 1-indexed `layer` of an `n_layers`-deep model.
    pub fn rho(&self, layer: usize, n_layers: usize) -> f64 {
        assert!(layer >= 1 && layer <= n_layers, "layer out of range");
        let lp = self.l_p.clamp(1, n_layers);
        if layer <= lp {
            let denom = (lp.max(2) - 1) as f64;
            let frac = (layer as f64 - lp as f64) / denom;
            self.rho_p * ((self.rho_1 / self.rho_p).ln() * frac * frac).exp()
        } else {
            let denom = (n_layers - lp).max(1) as f64;
            let frac = (layer as f64 - lp as f64) / denom;
            self.rho_p * ((self.rho_l / self.rho_p).ln() * frac * frac).exp()
        }
    }

    /// Static per-layer update counts `k_l = ceil(N * rho(l))`, rounded up
    /// to a multiple of 8 — unaligned extents fall off XLA's vectorised
    /// fast path (mirror of schedule.py; see EXPERIMENTS.md §Perf).
    pub fn k_per_layer(&self, n_layers: usize, seq_len: usize) -> Vec<usize> {
        const ALIGN: usize = 8;
        (1..=n_layers)
            .map(|l| {
                let k = ((seq_len as f64 * self.rho(l, n_layers)).ceil() as usize).max(1);
                ((k + ALIGN - 1) / ALIGN * ALIGN).min(seq_len)
            })
            .collect()
    }

    pub fn mean_rho(&self, n_layers: usize) -> f64 {
        (1..=n_layers).map(|l| self.rho(l, n_layers)).sum::<f64>() / n_layers as f64
    }

    /// Cached steps needed for the in-graph proxy budget to recompute a
    /// whole row: the **slowest** layer bounds it, `max_l ⌈1/ρ(l)⌉`.  A
    /// mean-ρ̄ estimate under-counts low-ρ layers and declares rows healed
    /// before their stale entries were actually recomputed — the budget cap
    /// is derived from the schedule, never an arbitrary constant.
    pub fn heal_steps(&self, n_layers: usize) -> usize {
        (1..=n_layers)
            .map(|l| {
                let r = self.rho(l, n_layers);
                if r.is_finite() && r > 0.0 {
                    (1.0 / r).ceil() as usize
                } else {
                    1
                }
            })
            .max()
            .unwrap_or(1)
            .max(1)
    }
}

/// Fit Eq. 5 to a measured drift profile — mirror of
/// `schedule.fit_piecewise_gaussian` (used by the Table 6 bench).
pub fn fit_piecewise_gaussian(drift: &[f64], rho_cap: f64) -> RhoSchedule {
    assert!(drift.len() >= 2, "need at least two layers");
    let eps = 1e-4;
    let d: Vec<f64> = drift.iter().map(|&x| x.clamp(eps, rho_cap)).collect();
    let n = d.len();
    let lp = d
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i + 1)
        .unwrap();
    let rho_p = d[lp - 1];

    let fit_side = |layers: &[usize], denom: usize| -> f64 {
        let (mut num, mut den) = (0.0, 0.0);
        for &l in layers {
            let x = ((l as f64 - lp as f64) / denom as f64).powi(2);
            let y = (d[l - 1] / rho_p).ln();
            num += x * y;
            den += x * x;
        }
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    };

    let left: Vec<usize> = (1..=lp).collect();
    let right: Vec<usize> = (lp..=n).collect();
    let c1 = fit_side(&left, (lp - 1).max(1));
    let cl = fit_side(&right, (n - lp).max(1));
    let rho_1 = (rho_p * c1.min(0.0).exp()).min(rho_cap).max(eps);
    let rho_l = (rho_p * cl.min(0.0).exp()).min(rho_cap).max(eps);
    RhoSchedule { l_p: lp, rho_p, rho_1, rho_l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_flat() {
        let s = RhoSchedule::uniform(0.25);
        for l in 1..=8 {
            assert!((s.rho(l, 8) - 0.25).abs() < 1e-12);
        }
        assert_eq!(s.k_per_layer(8, 128), vec![32; 8]);
    }

    #[test]
    fn peak_at_lp() {
        let s = RhoSchedule { l_p: 4, rho_p: 0.25, rho_1: 0.03, rho_l: 0.13 };
        let rhos: Vec<f64> = (1..=8).map(|l| s.rho(l, 8)).collect();
        let max = rhos.iter().cloned().fold(f64::MIN, f64::max);
        assert!((rhos[3] - max).abs() < 1e-12, "{rhos:?}");
        assert!((rhos[3] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn boundaries_hit_fitted_values() {
        let s = RhoSchedule { l_p: 4, rho_p: 0.25, rho_1: 0.03, rho_l: 0.13 };
        assert!((s.rho(1, 8) - 0.03).abs() < 1e-9);
        assert!((s.rho(8, 8) - 0.13).abs() < 1e-9);
    }

    /// Degenerate-geometry table: peak at the first layer, peak at the
    /// last layer, and a single-layer model.  These hit the `lp.max(2)` /
    /// `(n_layers - lp).max(1)` denominator guards — a regression here
    /// would divide by zero or put the peak on the wrong side.
    #[test]
    fn rho_edge_case_table() {
        // (l_p, n_layers, layer, expected)
        let s = |l_p| RhoSchedule { l_p, rho_p: 0.4, rho_1: 0.1, rho_l: 0.2 };
        let cases: &[(usize, usize, usize, f64)] = &[
            // Peak at layer 1: the left branch collapses to rho_p at l=1,
            // the right branch decays towards rho_l at l=n.
            (1, 8, 1, 0.4),
            (1, 8, 8, 0.2),
            // Peak at the last layer: the right branch is empty, the left
            // branch starts from rho_1 at l=1.
            (8, 8, 8, 0.4),
            (8, 8, 1, 0.1),
            // Single-layer model: the only layer is the peak.
            (1, 1, 1, 0.4),
            // l_p beyond n_layers clamps to n_layers.
            (9, 4, 4, 0.4),
        ];
        for &(l_p, n_layers, layer, want) in cases {
            let got = s(l_p).rho(layer, n_layers);
            assert!(
                (got - want).abs() < 1e-9,
                "rho(l={layer}, n={n_layers}) with l_p={l_p}: got {got}, want {want}"
            );
        }
        // Interior values stay within (min(rho_1, rho_l), rho_p] on every
        // degenerate geometry.
        for &(l_p, n_layers) in &[(1usize, 8usize), (8, 8), (1, 1), (2, 2)] {
            let sched = s(l_p);
            for l in 1..=n_layers {
                let r = sched.rho(l, n_layers);
                assert!(
                    r <= 0.4 + 1e-12 && r >= 0.1 - 1e-12,
                    "rho out of band: l_p={l_p} n={n_layers} l={l} -> {r}"
                );
                assert!(r.is_finite());
            }
        }
    }

    #[test]
    fn k_per_layer_bounds() {
        crate::util::proptest::check(
            "k_per_layer_in_bounds",
            |r| {
                let lp = r.range(1, 9);
                let rp = 0.05 + r.f64() * 0.45;
                RhoSchedule {
                    l_p: lp,
                    rho_p: rp,
                    rho_1: (0.01 + r.f64() * rp).min(rp),
                    rho_l: (0.01 + r.f64() * rp).min(rp),
                }
            },
            |s| {
                let ks = s.k_per_layer(8, 128);
                let kp_aligned = ((128.0 * s.rho_p).ceil() as usize).div_ceil(8) * 8;
                for (i, &k) in ks.iter().enumerate() {
                    if k < 1 || k > 128 {
                        return Err(format!("k[{i}]={k} out of range"));
                    }
                    if k % 8 != 0 && k != 128 {
                        return Err(format!("k[{i}]={k} not aligned"));
                    }
                    if k > kp_aligned.min(128) {
                        return Err(format!("k[{i}]={k} exceeds aligned peak {kp_aligned}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fit_recovers_exact_family() {
        let truth = RhoSchedule { l_p: 4, rho_p: 0.30, rho_1: 0.05, rho_l: 0.12 };
        let profile: Vec<f64> = (1..=8).map(|l| truth.rho(l, 8)).collect();
        let fit = fit_piecewise_gaussian(&profile, 1.0);
        assert_eq!(fit.l_p, 4);
        assert!((fit.rho_p - 0.30).abs() < 1e-9);
        assert!((fit.rho_1 - 0.05).abs() < 1e-6, "{fit:?}");
        assert!((fit.rho_l - 0.12).abs() < 1e-6, "{fit:?}");
    }

    #[test]
    fn heal_steps_bounded_by_slowest_layer() {
        // Uniform 0.25: every layer needs 4 steps.
        assert_eq!(RhoSchedule::uniform(0.25).heal_steps(8), 4);
        // Skewed: the rho_1 = 0.05 boundary layer dominates (20 steps),
        // never the mean (~8 would declare low-ρ rows healed early).
        let s = RhoSchedule { l_p: 4, rho_p: 0.5, rho_1: 0.05, rho_l: 0.25 };
        assert_eq!(s.heal_steps(8), 20);
        // Degenerate single layer.
        assert_eq!(RhoSchedule::uniform(1.0).heal_steps(1), 1);
    }

    #[test]
    fn fit_handles_flat_profile() {
        let fit = fit_piecewise_gaussian(&[0.1; 6], 1.0);
        for l in 1..=6 {
            assert!((fit.rho(l, 6) - 0.1).abs() < 1e-9);
        }
    }
}
