//! Char-level tokenizer, mirror of `python/compile/corpus.py`.
//!
//! The charset is also shipped in `artifacts/index.json`; `Tokenizer::from_manifest`
//! builds from that (and the unit tests pin the compiled-in copy to the same
//! constants so drift between the layers is caught at test time).

pub const PAD: i32 = 0;
pub const MASK: i32 = 1;
pub const BOS: i32 = 2;
pub const EOS: i32 = 3;

pub const SPECIALS: [&str; 4] = ["<pad>", "<mask>", "<bos>", "<eos>"];
pub const CHARSET: &str = "0123456789abcdefghijklmnopqrstuvwxyz+-*/=()<>?:;,.#@!| ";
pub const VOCAB_SIZE: usize = 64;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    charset: Vec<char>,
    to_id: [i32; 128],
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new(CHARSET)
    }
}

impl Tokenizer {
    pub fn new(charset: &str) -> Tokenizer {
        let chars: Vec<char> = charset.chars().collect();
        let mut to_id = [-1i32; 128];
        for (i, &c) in chars.iter().enumerate() {
            to_id[c as usize] = (i + SPECIALS.len()) as i32;
        }
        Tokenizer { charset: chars, to_id }
    }

    pub fn from_manifest(charset: &str) -> Tokenizer {
        Tokenizer::new(charset)
    }

    /// Encode text; unknown characters are an error (the grammar is closed).
    pub fn encode(&self, text: &str) -> anyhow::Result<Vec<i32>> {
        text.chars()
            .map(|c| {
                let i = (c as usize).checked_sub(0).filter(|&i| i < 128);
                match i.map(|i| self.to_id[i]) {
                    Some(id) if id >= 0 => Ok(id),
                    _ => anyhow::bail!("unknown char {c:?}"),
                }
            })
            .collect()
    }

    /// Decode ids; specials and out-of-range ids are dropped.
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter_map(|&id| {
                let i = id as usize;
                if id < SPECIALS.len() as i32 {
                    None
                } else {
                    self.charset.get(i - SPECIALS.len()).copied()
                }
            })
            .collect()
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::default();
        let s = "#q rev(abc)=?#a cba;";
        let ids = t.encode(s).unwrap();
        assert_eq!(t.decode(&ids), s);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let t = Tokenizer::default();
        let mut ids = vec![BOS];
        ids.extend(t.encode("ab").unwrap());
        ids.push(EOS);
        ids.push(PAD);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn rejects_unknown() {
        let t = Tokenizer::default();
        assert!(t.encode("Ü").is_err());
        assert!(t.encode("A").is_err()); // uppercase not in grammar
    }

    #[test]
    fn ids_match_python_layout() {
        let t = Tokenizer::default();
        // '0' is the first charset char -> id 4; space is the last.
        assert_eq!(t.encode("0").unwrap(), vec![4]);
        assert_eq!(
            t.encode(" ").unwrap(),
            vec![4 + CHARSET.chars().count() as i32 - 1]
        );
    }

    #[test]
    fn property_roundtrip_random() {
        let t = Tokenizer::default();
        crate::util::proptest::check(
            "tokenizer_roundtrip",
            |r| {
                let cs: Vec<char> = CHARSET.chars().collect();
                (0..r.range(0, 40)).map(|_| *r.choice(&cs)).collect::<String>()
            },
            |s| {
                let ids = t.encode(s).map_err(|e| e.to_string())?;
                if t.decode(&ids) == *s {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }
}
