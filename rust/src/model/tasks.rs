//! Synthetic task-suite generators — Rust mirror of `python/compile/corpus.py`.
//!
//! The coordinator and the benches generate their own workloads (prompt +
//! masked generation region + ground-truth answer), so accuracy is measured
//! natively in Rust without touching Python at serving time.  Each suite
//! mirrors one paper benchmark's decode configuration (paper Table 7).

use super::tokenizer::{Tokenizer, BOS, EOS, MASK, PAD};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Gsm8kS,
    GpqaS,
    MathS,
    BbhS,
    MmluS,
    MbppS,
    HeS,
}

pub const ALL_TASKS: [Task; 7] =
    [Task::Gsm8kS, Task::GpqaS, Task::MathS, Task::BbhS, Task::MmluS, Task::MbppS, Task::HeS];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Gsm8kS => "gsm8k_s",
            Task::GpqaS => "gpqa_s",
            Task::MathS => "math_s",
            Task::BbhS => "bbh_s",
            Task::MmluS => "mmlu_s",
            Task::MbppS => "mbpp_s",
            Task::HeS => "he_s",
        }
    }

    pub fn paper_name(&self) -> &'static str {
        match self {
            Task::Gsm8kS => "GSM8K",
            Task::GpqaS => "GPQA",
            Task::MathS => "MATH500",
            Task::BbhS => "BBH",
            Task::MmluS => "MMLU-pro",
            Task::MbppS => "MBPP",
            Task::HeS => "HumanEval",
        }
    }

    pub fn from_name(s: &str) -> Option<Task> {
        ALL_TASKS.iter().copied().find(|t| t.name() == s)
    }

    /// Few-shot exemplars in the prompt (paper Table 7, scaled).
    pub fn n_shot(&self) -> usize {
        match self {
            Task::Gsm8kS | Task::GpqaS | Task::MathS => 2,
            Task::BbhS | Task::MmluS | Task::MbppS => 1,
            Task::HeS => 0,
        }
    }

    /// Generation-region length (paper Table 7, scaled).
    pub fn gen_len(&self) -> usize {
        match self {
            Task::GpqaS => 32,
            _ => 64,
        }
    }

    /// Semi-AR block length for Fast-dLLM (paper Table 7, scaled).
    pub fn block_len(&self) -> usize {
        match self {
            Task::Gsm8kS => 8,
            Task::BbhS | Task::MmluS => 64,
            _ => 16,
        }
    }

    /// One (question, answer) pair — mirror of the python generators
    /// (statistically, not bitwise: the RNGs differ).
    pub fn gen(&self, rng: &mut Rng) -> (String, String) {
        const LETTERS: &[u8] = b"abcdefghijklmnopqrstuvwxyz";
        match self {
            Task::Gsm8kS => {
                let a = rng.below(10);
                let b = rng.below(10);
                (format!("{a}+{b}=?"), (a + b).to_string())
            }
            Task::GpqaS => {
                let idx = rng.sample_indices(26, 4);
                let (p, q, r, s) = (
                    LETTERS[idx[0]] as char,
                    LETTERS[idx[1]] as char,
                    LETTERS[idx[2]] as char,
                    LETTERS[idx[3]] as char,
                );
                let mut facts = vec![format!("{p}>{q}"), format!("{r}>{s}")];
                rng.shuffle(&mut facts);
                let (query, ans) = if rng.bool(0.5) { (r, s) } else { (p, q) };
                (format!("{};{};{query}>?", facts[0], facts[1]), ans.to_string())
            }
            Task::MathS => {
                let a = rng.range(2, 10);
                let b = rng.range(2, 10);
                (format!("{a}*{b}=?"), (a * b).to_string())
            }
            Task::BbhS => {
                let s: String = (0..3).map(|_| LETTERS[rng.range(0, 26)] as char).collect();
                let rev: String = s.chars().rev().collect();
                (format!("rev({s})=?"), rev)
            }
            Task::MmluS => {
                let vals = rng.sample_indices(10, 3);
                let key = rng.range(0, 3);
                let opts: Vec<String> = "abc"
                    .chars()
                    .zip(&vals)
                    .map(|(o, v)| format!("{o}:{v}"))
                    .collect();
                (
                    format!("{} get {}?", opts.join(" "), "abc".chars().nth(key).unwrap()),
                    vals[key].to_string(),
                )
            }
            Task::MbppS => {
                let s: String = (0..2).map(|_| LETTERS[rng.range(0, 26)] as char).collect();
                (format!("dup({s})=?"), format!("{s}{s}"))
            }
            Task::HeS => {
                let start = rng.range(0, 24);
                let s: String = (0..2).map(|i| (b'a' + (start + i) as u8) as char).collect();
                let nxt: String = s.chars().map(|c| ((c as u8) + 1) as char).collect();
                (format!("nxt({s})=?"), nxt)
            }
        }
    }
}

/// One serving sample: tokens with a masked generation region + ground truth.
#[derive(Debug, Clone)]
pub struct Sample {
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub answer: String,
    pub task: Task,
}

/// Build the few-shot prompt text for `question` (mirror of corpus.render_prompt).
pub fn render_prompt(task: Task, rng: &mut Rng, question: &str) -> String {
    let mut out = String::new();
    for _ in 0..task.n_shot() {
        let (q, a) = task.gen(rng);
        out.push_str(&format!("#q {q}#a {a};"));
    }
    out.push_str(&format!("#q {question}#a "));
    out
}

/// Build one sample of total length `seq_len` (mirror of corpus.make_sample).
pub fn make_sample(task: Task, rng: &mut Rng, tok: &Tokenizer, seq_len: usize) -> Sample {
    let (q, answer) = task.gen(rng);
    let prompt = render_prompt(task, rng, &q);
    let mut ids = vec![BOS];
    ids.extend(tok.encode(&prompt).expect("grammar closed"));
    let prompt_len = ids.len();
    let gen_region = task.gen_len().min(seq_len.saturating_sub(prompt_len));
    assert!(gen_region > 0, "prompt too long for seq_len={seq_len}");
    let mut tokens = vec![PAD; seq_len];
    tokens[..prompt_len].copy_from_slice(&ids);
    for t in tokens.iter_mut().take(prompt_len + gen_region).skip(prompt_len) {
        *t = MASK;
    }
    Sample { tokens, prompt_len, answer, task }
}

/// Extract the generated answer (mirror of corpus.extract_answer).
pub fn extract_answer(tok: &Tokenizer, tokens: &[i32], prompt_len: usize) -> String {
    let mut ids = Vec::new();
    for &t in &tokens[prompt_len.min(tokens.len())..] {
        if t == EOS || t == PAD || t == MASK {
            break;
        }
        ids.push(t);
    }
    tok.decode(&ids).trim_end_matches(';').trim().to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_samples() {
        let tok = Tokenizer::default();
        let mut rng = Rng::new(1);
        for task in ALL_TASKS {
            for _ in 0..20 {
                let s = make_sample(task, &mut rng, &tok, 128);
                assert_eq!(s.tokens.len(), 128);
                assert_eq!(s.tokens[0], BOS);
                assert!(s.tokens.contains(&MASK));
                assert!(!s.answer.is_empty());
                // every answer is encodable
                tok.encode(&s.answer).unwrap();
            }
        }
    }

    #[test]
    fn answers_are_correct_for_known_cases() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let (q, a) = Task::BbhS.gen(&mut rng);
            // rev(s)=? -> reversed
            let inner = &q["rev(".len()..q.len() - ")=?".len()];
            assert_eq!(a, inner.chars().rev().collect::<String>());
        }
        for _ in 0..50 {
            let (q, a) = Task::MathS.gen(&mut rng);
            let (l, r) = q[..q.len() - 2].split_once('*').unwrap();
            assert_eq!(a.parse::<usize>().unwrap(), l.parse::<usize>().unwrap() * r.parse::<usize>().unwrap());
        }
    }

    #[test]
    fn extract_answer_stops_at_eos() {
        let tok = Tokenizer::default();
        let mut toks = vec![BOS];
        toks.extend(tok.encode("#a ").unwrap());
        let plen = toks.len();
        toks.extend(tok.encode("42").unwrap());
        toks.push(EOS);
        toks.extend(tok.encode("junk").unwrap());
        assert_eq!(extract_answer(&tok, &toks, plen), "42");
    }

    #[test]
    fn gen_region_masked_then_pad() {
        let tok = Tokenizer::default();
        let mut rng = Rng::new(3);
        let s = make_sample(Task::GpqaS, &mut rng, &tok, 128);
        let gen_end = s.prompt_len + Task::GpqaS.gen_len();
        for (i, &t) in s.tokens.iter().enumerate() {
            if i < s.prompt_len {
                assert_ne!(t, MASK);
            } else if i < gen_end {
                assert_eq!(t, MASK);
            } else {
                assert_eq!(t, PAD);
            }
        }
    }

    #[test]
    fn property_prompt_fits() {
        let tok = Tokenizer::default();
        crate::util::proptest::check(
            "prompt_fits_128",
            |r| (r.next_u64(), ALL_TASKS[r.range(0, 7)]),
            |&(seed, task)| {
                let mut rng = Rng::new(seed);
                let s = make_sample(task, &mut rng, &Tokenizer::default(), 128);
                let _ = &tok;
                if s.prompt_len + 8 > 128 {
                    return Err(format!("prompt too long: {}", s.prompt_len));
                }
                Ok(())
            },
        );
    }
}
