//! Vendored minimal `anyhow` substitute (the offline registry has no
//! crates.io access — DESIGN.md §2).  API-compatible with the subset the
//! SPA-Cache tree uses: [`Error`], [`Result`], the [`Context`] extension
//! trait on `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!` macros.
//!
//! Context is flattened eagerly into the message (`"ctx: cause"`), while the
//! original error is kept as `source()`-style text for `{:#}`/`{:?}`.

use std::fmt;

/// A flattened error: message chain, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain.iter().skip(1).enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// The classic anyhow blanket conversion: any std error flows in via `?`.
// (Coherent because `Error` itself deliberately does not implement
// `std::error::Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, mirroring anyhow's extension trait.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))
            ));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
            .context("writing checkpoint")
    }

    #[test]
    fn context_chains() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "writing checkpoint");
        assert!(format!("{e:#}").contains("disk on fire"));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing key").unwrap_err();
        assert_eq!(e.root_cause(), "missing key");
    }

    #[test]
    fn macros() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Ok(n)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().root_cause().contains("12"));
        assert!(f(5).is_err());
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
