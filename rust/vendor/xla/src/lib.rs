//! Vendored `xla` API surface (DESIGN.md §2).
//!
//! The real dependency is a fork of `xla-rs` exposing `execute_b_untuple`
//! over PJRT.  This vendored crate keeps the whole SPA-Cache tree compiling
//! and unit-testable in environments without the PJRT runtime:
//!
//! * [`Literal`] is **fully functional** host-side (bytes + shape + dtype),
//!   so every tensor/manifest/decode unit test runs for real.
//! * [`PjRtClient::cpu`] returns an error, which the engine surfaces as
//!   "PJRT unavailable"; artifact-gated integration tests skip gracefully.
//!
//! Swapping the real runtime back in is a one-line change in the root
//! `Cargo.toml` (point the `xla` path dependency at the fork).

use std::fmt;
use std::path::Path;

/// Error type for every fallible operation in this crate.
#[derive(Debug, Clone)]
pub struct XlaError {
    pub msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> XlaError {
        XlaError { msg: msg.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

const STUB_MSG: &str = "PJRT runtime unavailable: spa-cache was built against the vendored \
                        xla stub (point the `xla` path dependency at the PJRT fork to enable \
                        device execution)";

/// XLA primitive element types used by the SPA-Cache artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(&self) -> usize {
        4
    }
}

/// Host native types that map onto an [`ElementType`].
pub trait NativeType: Copy + 'static {
    const ELEMENT_TYPE: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
    fn to_le(self) -> [u8; 4];
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

/// A host-side tensor: dtype + dims + little-endian bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = dims.iter().product::<usize>() * ty.byte_size();
        if data.len() != want {
            return Err(XlaError::new(format!(
                "literal data size {} does not match shape {dims:?} ({want} bytes)",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::ELEMENT_TYPE != self.ty {
            return Err(XlaError::new(format!(
                "dtype mismatch: literal is {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Logical device shape of a buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct Shape {
    ty: ElementType,
    dims: Vec<usize>,
}

/// Array view of a [`Shape`].
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<usize>,
}

impl ArrayShape {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }
}

impl TryFrom<&Shape> for ArrayShape {
    type Error = XlaError;

    fn try_from(s: &Shape) -> Result<ArrayShape> {
        Ok(ArrayShape { ty: s.ty, dims: s.dims.clone() })
    }
}

/// A device buffer.  In the stub it wraps a host [`Literal`].
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }

    pub fn on_device_shape(&self) -> Result<Shape> {
        Ok(Shape { ty: self.lit.ty, dims: self.lit.dims.clone() })
    }

    /// Patch whole leading-dimension rows of a resident buffer in place
    /// from host data (`data` holds `rows.len()` consecutive rows).  The
    /// delta-upload hot path uses this to refresh only dirty batch rows
    /// while clean rows keep their device-resident bytes.
    pub fn copy_rows_from_host<T: NativeType>(
        &mut self,
        rows: &[usize],
        data: &[T],
    ) -> Result<()> {
        if T::ELEMENT_TYPE != self.lit.ty {
            return Err(XlaError::new(format!(
                "dtype mismatch: buffer is {:?}, patch is {:?}",
                self.lit.ty,
                T::ELEMENT_TYPE
            )));
        }
        let Some((&b, tail)) = self.lit.dims.split_first() else {
            return Err(XlaError::new("cannot row-patch a rank-0 buffer"));
        };
        let row_elems: usize = tail.iter().product();
        if data.len() != rows.len() * row_elems {
            return Err(XlaError::new(format!(
                "row patch carries {} elements for {} rows of {row_elems}",
                data.len(),
                rows.len()
            )));
        }
        let row_bytes = row_elems * self.lit.ty.byte_size();
        for (i, &row) in rows.iter().enumerate() {
            if row >= b {
                return Err(XlaError::new(format!(
                    "row {row} out of range for leading dim {b}"
                )));
            }
            let dst = &mut self.lit.data[row * row_bytes..(row + 1) * row_bytes];
            for (j, x) in data[i * row_elems..(i + 1) * row_elems].iter().enumerate() {
                dst[j * 4..(j + 1) * 4].copy_from_slice(&x.to_le());
            }
        }
        Ok(())
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| XlaError::new(format!("reading {:?}: {e}", path.as_ref())))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation (opaque in the stub).
#[derive(Debug)]
pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

/// A compiled executable.  Execution is unavailable in the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    /// Untupled execution: one `Vec<PjRtBuffer>` per device.
    pub fn execute_b_untuple<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(STUB_MSG))
    }
}

/// The PJRT client.  `cpu()` fails in the stub, so the engine reports the
/// runtime as unavailable before any execution is attempted.
#[derive(Debug)]
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::new(STUB_MSG))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for x in data {
            bytes.extend_from_slice(&x.to_le());
        }
        Ok(PjRtBuffer {
            lit: Literal::create_from_shape_and_untyped_data(T::ELEMENT_TYPE, dims, &bytes)?,
        })
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<f32> = vec![1.5, -2.0, 0.0, 4.0];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes)
                .unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err(), "dtype mismatch must fail");
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[3], &[0u8; 4])
                .is_err()
        );
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }

    #[test]
    fn row_patch_updates_only_named_rows() {
        let client = PjRtClient { _p: () };
        let data: Vec<i32> = (0..12).collect(); // 3 rows × 4
        let mut buf = client.buffer_from_host_buffer::<i32>(&data, &[3, 4], None).unwrap();
        buf.copy_rows_from_host::<i32>(&[0, 2], &[100, 101, 102, 103, 200, 201, 202, 203])
            .unwrap();
        let got = buf.to_literal_sync().unwrap().to_vec::<i32>().unwrap();
        assert_eq!(got, vec![100, 101, 102, 103, 4, 5, 6, 7, 200, 201, 202, 203]);
        // Validation: dtype, bounds, arity.
        assert!(buf.copy_rows_from_host::<f32>(&[0], &[1.0; 4]).is_err());
        assert!(buf.copy_rows_from_host::<i32>(&[3], &[0; 4]).is_err());
        assert!(buf.copy_rows_from_host::<i32>(&[0], &[0; 3]).is_err());
    }
}
