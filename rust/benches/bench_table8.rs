//! Paper Table 8: the method lineup on LLaDA-1.5 (our warm-started
//! llada15_s), all seven tasks, plus peak cache memory per method.

use spa_cache::bench::runner::{eval_method, paper_methods, sample_count, task_samples};
use spa_cache::bench::{fmt_acc, fmt_tps, Table};
use spa_cache::model::tasks::ALL_TASKS;
use spa_cache::runtime::engine::Engine;
use spa_cache::util::cli::Args;

/// Cache-state bytes a method keeps resident per batch group (analytic).
fn cache_mib(engine: &Engine, model: &str, variant: &str) -> f64 {
    let v = match engine.manifest.variants.get(&format!("{model}__{variant}")) {
        Some(v) => v,
        None => return 0.0,
    };
    let mut bytes = 0usize;
    for i in &v.inputs {
        if i.name != "tokens" && i.name != "idx" {
            bytes += 4 * i.shape.iter().product::<usize>();
        }
    }
    bytes as f64 / (1024.0 * 1024.0)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let n = args.usize_or("samples", sample_count(!args.flag("full")));
    let seed = args.u64_or("seed", 42);
    let model = args.str_or("model", "llada15_s");

    let mut table = Table::new(
        &format!("Table 8 — LLaDA-1.5 analogue ({model})"),
        &["task", "method", "TPS", "TTFT(ms)", "accuracy", "cache MiB"],
    );
    for task in ALL_TASKS {
        let samples = task_samples(&engine, task, n, seed);
        let mut baseline_tps = 0.0;
        let mut reference = None;
        for (name, spec, mode) in paper_methods(task.block_len().min(32)) {
            let mem = match name {
                "baseline" => 0.0,
                "+ dLLM-Cache" => cache_mib(&engine, &model, "spa_value_u25"),
                "+ Fast-dLLM" => cache_mib(&engine, &model, "manual_k16"),
                _ => cache_mib(&engine, &model, "spa_default"),
            };
            let r = eval_method(&engine, &model, spec, mode, &samples, reference.as_ref())?;
            if name == "baseline" {
                baseline_tps = r.tps;
            }
            table.row(vec![
                task.name().into(),
                name.into(),
                fmt_tps(r.tps, baseline_tps),
                format!("{:.1}", r.ttft_ms),
                fmt_acc(r.accuracy, r.n),
                format!("{mem:.1}"),
            ]);
            if name == "baseline" {
                reference = Some(r);
            }
        }
    }
    table.print();
    table.append_to("bench_results.txt");
    Ok(())
}
