//! Paper Table 4: ablation of the identifier and the adaptive budget.
//! Rows: none / value@25% / singular@25% / singular@adaptive /
//! singular@uniform-mean — isolating each contribution.

use spa_cache::bench::runner::{eval_method, sample_count, task_samples};
use spa_cache::bench::{fmt_acc, Table};
use spa_cache::coordinator::decode::UnmaskMode;
use spa_cache::coordinator::cache::MethodSpec;
use spa_cache::model::tasks::Task;
use spa_cache::runtime::engine::Engine;
use spa_cache::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let n = args.usize_or("samples", sample_count(!args.flag("full")));
    let samples = task_samples(&engine, Task::Gsm8kS, n, args.u64_or("seed", 42));
    let model = args.str_or("model", "llada_s");

    let rows: Vec<(&str, Option<&str>)> = vec![
        ("none (baseline)", None),
        ("value, uniform peak", Some("spa_value_u25")),
        ("singular16, uniform peak", Some("spa_singular16_u25")),
        ("singular16, adaptive (Eq.5)", Some("spa_default")),
        ("singular16, uniform @ adaptive mean", Some("spa_singular16_umean")),
    ];

    let mut table = Table::new(
        &format!("Table 4 — identifier x budget ablation, {model}, gsm8k_s"),
        &["identifier / budget", "peak rho", "avg rho", "TPS", "accuracy", "agreement"],
    );
    let mut reference = None;
    for (name, variant) in rows {
        let (spec, peak, mean) = match variant {
            None => (MethodSpec::Vanilla, 1.0, 1.0),
            Some(v) => {
                let info = engine.manifest.variant(&format!("{model}__{v}"))?;
                (
                    MethodSpec::Spa { variant: v.into(), refresh_interval: 0 },
                    info.schedule.rho_p,
                    info.mean_rho(),
                )
            }
        };
        let r = eval_method(
            &engine, &model, spec, UnmaskMode::Sequential, &samples, reference.as_ref(),
        )?;
        table.row(vec![
            name.into(),
            format!("{:.0}%", peak * 100.0),
            format!("{:.0}%", mean * 100.0),
            format!("{:.2}", r.tps),
            fmt_acc(r.accuracy, r.n),
            format!("{:.3}", r.agreement),
        ]);
        if variant.is_none() {
            reference = Some(r);
        }
    }
    table.print();
    table.append_to("bench_results.txt");
    Ok(())
}
