//! Paper Table 2: main results — {baseline, dLLM-Cache, Fast-dLLM, ours}
//! across the seven task suites on LLaDA-s and Dream-s.
//! Columns: TPS (with speedup), TTFT (ms), accuracy (±CI), agreement.
//!
//! Usage: cargo bench --bench bench_table2 [-- --samples 8 --models llada_s]

use spa_cache::bench::runner::{eval_method, paper_methods, sample_count, task_samples};
use spa_cache::bench::{fmt_acc, fmt_tps, Table};
use spa_cache::model::tasks::ALL_TASKS;
use spa_cache::runtime::engine::Engine;
use spa_cache::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let samples_n = args.usize_or("samples", sample_count(!args.flag("full")));
    let seed = args.u64_or("seed", 42);
    let models: Vec<String> = args
        .str_or("models", "llada_s,dream_s")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let only_task = args.get("task").map(|s| s.to_string());

    for model in &models {
        let mut table = Table::new(
            &format!("Table 2 — {model} (paper: {}, N={} samples/task)",
                engine.manifest.model(model)?.arch.name, samples_n),
            &["task", "method", "TPS", "TTFT(ms)", "accuracy", "agreement"],
        );
        for task in ALL_TASKS {
            if let Some(t) = &only_task {
                if t != task.name() {
                    continue;
                }
            }
            let samples = task_samples(&engine, task, samples_n, seed);
            let mut baseline_tps = 0.0;
            let mut reference = None;
            for (name, spec, mode) in paper_methods(task.block_len().min(32)) {
                let r = eval_method(&engine, model, spec, mode, &samples, reference.as_ref())?;
                if name == "baseline" {
                    baseline_tps = r.tps;
                }
                table.row(vec![
                    task.name().into(),
                    name.into(),
                    fmt_tps(r.tps, baseline_tps),
                    format!("{:.1}", r.ttft_ms),
                    fmt_acc(r.accuracy, r.n),
                    format!("{:.3}", r.agreement),
                ]);
                if name == "baseline" {
                    reference = Some(r);
                }
            }
        }
        table.print();
        table.append_to("bench_results.txt");
    }
    Ok(())
}
