//! Paper Figure 1 (+ Figure 7 with --extended): adjacent-step cosine
//! similarities of input / value / singular-proxy / attn-output / layer-
//! output features, from the probe artifact.  Fig 1 shows that input states
//! look uniformly stable while the proxy exposes the drift the FFN output
//! actually experiences.

use spa_cache::analysis::drift::{run_probe, CHANNELS};
use spa_cache::bench::Table;
use spa_cache::coordinator::group::pack_group;
use spa_cache::model::tasks::{make_sample, ALL_TASKS};
use spa_cache::model::tokenizer::Tokenizer;
use spa_cache::runtime::engine::Engine;
use spa_cache::util::cli::Args;
use spa_cache::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let model = args.str_or("model", "llada_s");
    let steps = args.usize_or("steps", 16);
    let extended = args.flag("extended");

    let tok = Tokenizer::from_manifest(&engine.manifest.charset);
    let mut rng = Rng::new(args.u64_or("seed", 7));
    let (b, n) = (engine.manifest.batch, engine.manifest.seq_len);
    let samples: Vec<_> = (0..b)
        .map(|i| make_sample(ALL_TASKS[i % ALL_TASKS.len()], &mut rng, &tok, n))
        .collect();
    let (mut tokens, mut slots) = pack_group(&samples, b, n, 16);
    let profile = run_probe(&engine, &model, &mut tokens, &mut slots, steps, 0.6)?;

    let sims = profile.mean_sims();
    let mut table = Table::new(
        &format!(
            "Figure 1{} — adjacent-step similarity per layer, {model} ({} steps)",
            if extended { "/7 (extended)" } else { "" },
            profile.steps.len()
        ),
        &["layer", CHANNELS[0], CHANNELS[1], CHANNELS[2], CHANNELS[3], CHANNELS[4]],
    );
    for (i, row) in sims.iter().enumerate() {
        table.row(vec![
            format!("{}", i + 1),
            format!("{:.4}", row[0]),
            format!("{:.4}", row[1]),
            format!("{:.4}", row[2]),
            format!("{:.4}", row[3]),
            format!("{:.4}", row[4]),
        ]);
    }
    table.print();
    table.append_to("bench_results.txt");

    // Headline check of Fig 1: input states look stable while the proxy
    // tracks the drift visible in the layer output.
    let avg = |c: usize| sims.iter().map(|r| r[c]).sum::<f64>() / sims.len() as f64;
    println!(
        "input-sim mean {:.4} vs proxy-sim mean {:.4} vs output-sim mean {:.4}",
        avg(0), avg(2), avg(4)
    );
    println!(
        "proxy/value agreement (paper Fig 7: near-identical): |Δ| = {:.4}",
        (avg(2) - avg(1)).abs()
    );

    if extended {
        // per-step series for representative layers (paper Fig 7 layout)
        let l = profile.n_layers;
        let picks = [0, l / 3, 2 * l / 3, l - 1];
        let mut t2 = Table::new(
            "Figure 7 — per-step output similarity at representative layers",
            &["step", "L1", "Lmid1", "Lmid2", "Llast"],
        );
        for (si, s) in profile.steps.iter().enumerate().skip(1) {
            t2.row(vec![
                format!("{si}"),
                format!("{:.4}", s.mean[picks[0]][4]),
                format!("{:.4}", s.mean[picks[1]][4]),
                format!("{:.4}", s.mean[picks[2]][4]),
                format!("{:.4}", s.mean[picks[3]][4]),
            ]);
        }
        t2.print();
        t2.append_to("bench_results.txt");
    }
    Ok(())
}
