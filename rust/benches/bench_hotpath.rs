//! §Hotpath: host-side micro-benches for the PR-6 raw-speed work.
//! Artifact-free (no compiled variants, no PJRT) so it runs on any checkout:
//! exercises the exact host primitives the serving hot path is built on —
//! arena-backed zero staging, `TokenDelta` row patching, and the sharded
//! top-k used by the parallel sampler.  Feeds the ledger methodology note
//! in DESIGN.md §10.

use spa_cache::bench::{time_ms, Table};
use spa_cache::coordinator::cache::prefix::{chain_key, prefix_key, PREFIX_SEED};
use spa_cache::coordinator::cache::{DeltaUpload, PrefixStore, TokenDelta};
use spa_cache::runtime::tensor::{literal_f32, literal_i32, literal_zeros_f32};
use spa_cache::util::cli::Args;
use spa_cache::util::rng::Rng;
use spa_cache::util::topk::{top_k_desc, top_k_desc_rows};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let iters = args.usize_or("iters", 30);
    let b = args.usize_or("rows", 32);
    let n = args.usize_or("seq", 256);
    let v = args.usize_or("vocab", 4096);
    let k = args.usize_or("k", 16);

    // --- arena vs fresh-alloc zero upload staging -----------------------
    // `zero_caches` used to build `vec![0.0; elems]` per cold admission;
    // the engine arena now keeps one zero template per shape.  Compare the
    // literal build with a fresh zeroed vec each iter against one reusing
    // a preallocated staging buffer.
    let cache_shape = [b, n, 64];
    let elems = b * n * 64;
    let mut table = Table::new(
        &format!("Hotpath — zero staging, shape {b}x{n}x64 ({elems} f32)"),
        &["variant", "mean ms", "p50", "p90"],
    );
    let s = time_ms(3, iters, || {
        literal_zeros_f32(&cache_shape).unwrap();
    });
    table.row(vec![
        "fresh-alloc".into(),
        format!("{:.3}", s.mean),
        format!("{:.3}", s.p50),
        format!("{:.3}", s.p90),
    ]);
    let staging = vec![0.0f32; elems];
    let s = time_ms(3, iters, || {
        literal_f32(&cache_shape, &staging).unwrap();
    });
    table.row(vec![
        "arena".into(),
        format!("{:.3}", s.mean),
        format!("{:.3}", s.p50),
        format!("{:.3}", s.p90),
    ]);
    table.print();
    table.append_to("bench_results.txt");

    // --- delta vs full token upload at varying dirty fractions ----------
    // Full path rebuilds the [b, n] i32 literal every step; delta path
    // plans against the host mirror and copies only the changed rows into
    // the simulated device buffer.  Both closures mutate the same number
    // of rows per iter so the compare is fair.
    let mut table = Table::new(
        &format!("Hotpath — token upload, B={b} N={n}"),
        &["variant", "dirty", "mean ms", "p50", "rows/step"],
    );
    let mut rng = Rng::new(11);
    let base: Vec<i32> = (0..b * n).map(|_| rng.below(30000) as i32).collect();
    for dirty_frac in [0.0f64, 0.125, 0.5, 1.0] {
        let dirty_rows = ((b as f64) * dirty_frac).round() as usize;

        // full upload baseline
        let mut tokens = base.clone();
        let mut cursor = 0usize;
        let s = time_ms(3, iters, || {
            for i in 0..dirty_rows {
                let r = (cursor + i) % b;
                tokens[r * n] = tokens[r * n].wrapping_add(1);
            }
            cursor = (cursor + dirty_rows.max(1)) % b;
            literal_i32(&[b, n], &tokens).unwrap();
        });
        table.row(vec![
            "full".into(),
            format!("{dirty_frac:.3}"),
            format!("{:.4}", s.mean),
            format!("{:.4}", s.p50),
            format!("{b}"),
        ]);

        // delta upload: plan + patch only dirty rows
        let mut tokens = base.clone();
        let mut device = base.clone();
        let mut delta = TokenDelta::default();
        delta.plan(&tokens, n); // absorb the initial Full
        let mut cursor = 0usize;
        let mut rows_copied = 0usize;
        let mut steps = 0usize;
        let s = time_ms(3, iters, || {
            for i in 0..dirty_rows {
                let r = (cursor + i) % b;
                tokens[r * n] = tokens[r * n].wrapping_add(1);
            }
            cursor = (cursor + dirty_rows.max(1)) % b;
            match delta.plan(&tokens, n) {
                DeltaUpload::Full => device.copy_from_slice(&tokens),
                DeltaUpload::Patch => {
                    for (i, &r) in delta.rows().iter().enumerate() {
                        device[r * n..(r + 1) * n]
                            .copy_from_slice(&delta.staged()[i * n..(i + 1) * n]);
                    }
                    rows_copied += delta.rows().len();
                }
            }
            steps += 1;
        });
        assert_eq!(device, tokens, "delta patching must track the full state");
        table.row(vec![
            "delta".into(),
            format!("{dirty_frac:.3}"),
            format!("{:.4}", s.mean),
            format!("{:.4}", s.p50),
            format!("{:.1}", rows_copied as f64 / steps.max(1) as f64),
        ]);
    }
    table.print();
    table.append_to("bench_results.txt");

    // --- serial vs sharded host top-k ------------------------------------
    // The sampler's O(B·V) top-k now runs through `par_row_chunks`; the
    // sharded variant must agree with the serial loop and win once the
    // total work clears the parallel threshold.
    let mut table = Table::new(
        &format!("Hotpath — top-k, B={b} V={v} k={k}"),
        &["variant", "mean ms", "p50", "p90"],
    );
    let scores: Vec<f32> = (0..b * v).map(|_| rng.f64() as f32).collect();
    let serial: Vec<Vec<usize>> =
        scores.chunks_exact(v).map(|row| top_k_desc(row, k)).collect();
    assert_eq!(serial, top_k_desc_rows(&scores, v, k), "sharded top-k must match serial");
    let s = time_ms(3, iters, || {
        for row in scores.chunks_exact(v) {
            top_k_desc(row, k);
        }
    });
    table.row(vec![
        "serial".into(),
        format!("{:.3}", s.mean),
        format!("{:.3}", s.p50),
        format!("{:.3}", s.p90),
    ]);
    let s = time_ms(3, iters, || {
        top_k_desc_rows(&scores, v, k);
    });
    table.row(vec![
        "sharded".into(),
        format!("{:.3}", s.mean),
        format!("{:.3}", s.p50),
        format!("{:.3}", s.p90),
    ]);
    table.print();
    table.append_to("bench_results.txt");

    // --- incremental prefix hashing vs full rehash ------------------------
    // A chat session extends its transcript by a handful of tokens per
    // turn; the admission path must not pay O(prompt) hashing per turn.
    // Compare rehashing the whole prompt each turn against extending the
    // running chain key by only the new suffix.
    let turns = 64usize;
    let per_turn = 16usize;
    let prompt: Vec<i32> = (0..turns * per_turn).map(|_| rng.below(30000) as i32).collect();
    let mut table = Table::new(
        &format!("Hotpath — prefix hashing, {turns} turns x {per_turn} tok"),
        &["variant", "mean ms", "p50", "p90"],
    );
    let s = time_ms(3, iters, || {
        let mut acc = 0u64;
        for t in 1..=turns {
            acc ^= prefix_key(&prompt[..t * per_turn]); // full rehash per turn
        }
        std::hint::black_box(acc);
    });
    table.row(vec![
        "full-rehash".into(),
        format!("{:.4}", s.mean),
        format!("{:.4}", s.p50),
        format!("{:.4}", s.p90),
    ]);
    let s = time_ms(3, iters, || {
        let mut acc = 0u64;
        let mut chain = PREFIX_SEED;
        for t in 0..turns {
            for &tok in &prompt[t * per_turn..(t + 1) * per_turn] {
                chain = chain_key(chain, tok); // extend by the suffix only
            }
            acc ^= chain;
        }
        std::hint::black_box(acc);
    });
    table.row(vec![
        "incremental".into(),
        format!("{:.4}", s.mean),
        format!("{:.4}", s.p50),
        format!("{:.4}", s.p90),
    ]);
    table.print();
    table.append_to("bench_results.txt");

    // --- prefix store insert + longest-match lookup ----------------------
    // The store sits on the admission path: donation (insert) on every
    // completion, longest-prefix lookup on every admission.  Population
    // mirrors a chat mix — many sessions, transcripts growing turn by turn.
    let sessions = 32usize;
    let mut table = Table::new(
        &format!("Hotpath — prefix store, {sessions} sessions x {turns} turns"),
        &["op", "mean ms", "p50", "p90"],
    );
    let rows: Vec<Vec<i32>> = (0..sessions)
        .map(|_| (0..turns * per_turn).map(|_| rng.below(30000) as i32).collect())
        .collect();
    let s = time_ms(3, iters, || {
        let mut store = PrefixStore::new(64 << 20);
        for row in &rows {
            for t in 1..=turns {
                store.insert(&row[..t * per_turn], "bench", None);
            }
        }
        std::hint::black_box(store.len());
    });
    table.row(vec![
        "insert".into(),
        format!("{:.4}", s.mean),
        format!("{:.4}", s.p50),
        format!("{:.4}", s.p90),
    ]);
    let mut store = PrefixStore::new(64 << 20);
    for row in &rows {
        for t in 1..=turns {
            store.insert(&row[..t * per_turn], "bench", None);
        }
    }
    let s = time_ms(3, iters, || {
        let mut depth = 0usize;
        for row in &rows {
            if let Some(hit) = store.lookup(row, "bench") {
                depth += hit.depth;
            }
        }
        std::hint::black_box(depth);
    });
    table.row(vec![
        "lookup".into(),
        format!("{:.4}", s.mean),
        format!("{:.4}", s.p50),
        format!("{:.4}", s.p90),
    ]);
    table.print();
    table.append_to("bench_results.txt");
    Ok(())
}
