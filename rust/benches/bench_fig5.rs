//! Paper Figure 5: anisotropy — cross-token cosine-similarity densities of
//! Value states (isotropic, centred near 0) versus attention outputs
//! (collapsed toward 1), explaining the attn-output identifier failure.

use spa_cache::analysis::anisotropy::{hist_mean, pair_similarity_hist};
use spa_cache::bench::Table;
use spa_cache::coordinator::group::pack_group;
use spa_cache::model::tasks::{make_sample, ALL_TASKS};
use spa_cache::model::tokenizer::Tokenizer;
use spa_cache::runtime::engine::Engine;
use spa_cache::runtime::tensor::{literal_i32, literal_zeros_f32, to_f32_vec};
use spa_cache::util::cli::Args;
use spa_cache::util::rng::Rng;
use xla::Literal;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let model = args.str_or("model", "llada_s");
    let pairs = args.usize_or("pairs", 4000);

    // One probe step gives the per-layer value states and attention outputs.
    let v = engine.load_variant(&format!("{model}__probe"))?;
    let (b, n) = (v.info.batch, v.info.seq_len);
    let arch = &engine.manifest.model(&model)?.arch;
    let tok = Tokenizer::from_manifest(&engine.manifest.charset);
    let mut rng = Rng::new(args.u64_or("seed", 7));
    let samples: Vec<_> = (0..b)
        .map(|i| make_sample(ALL_TASKS[i % ALL_TASKS.len()], &mut rng, &tok, n))
        .collect();
    let (tokens, _slots) = pack_group(&samples, b, n, 16);
    let tok_lit = literal_i32(&[b, n], &tokens)?;
    let records: Vec<Literal> = v
        .info
        .inputs
        .iter()
        .filter(|i| i.name != "tokens")
        .map(|i| literal_zeros_f32(&i.shape))
        .collect::<anyhow::Result<_>>()?;
    let mut refs: Vec<&Literal> = vec![&tok_lit];
    refs.extend(records.iter());
    let outs = engine.run(&v, &refs)?;
    // outputs: [logits, xin, val, prox, ao, out, sims]
    let val = to_f32_vec(&outs[2])?; // [L,B,N,d_kv]
    let ao = to_f32_vec(&outs[4])?; // [L,B,N,d_q]

    let l = arch.n_layers;
    let (dkv, dq) = (arch.n_kv_heads * arch.d_head, arch.n_heads * arch.d_head);
    let mut table = Table::new(
        &format!("Figure 5 — cross-token cosine similarity, {model}"),
        &["layer", "value mean", "attn-out mean", "value density", "attn-out density"],
    );
    for li in [0, l / 2, l - 1] {
        let vslice = &val[li * b * n * dkv..(li + 1) * b * n * dkv];
        let aslice = &ao[li * b * n * dq..(li + 1) * b * n * dq];
        let hv = pair_similarity_hist(vslice, b * n, dkv, pairs, &mut rng);
        let ha = pair_similarity_hist(aslice, b * n, dq, pairs, &mut rng);
        table.row(vec![
            format!("{}", li + 1),
            format!("{:.3}", hist_mean(&hv)),
            format!("{:.3}", hist_mean(&ha)),
            hv.sparkline(),
            ha.sparkline(),
        ]);
    }
    table.print();
    table.append_to("bench_results.txt");
    println!(
        "(paper Fig 5: attn-out similarities collapse toward 1 — the anisotropy \
         masking effect behind Table 1's attn-output identifier failure)"
    );
    Ok(())
}
