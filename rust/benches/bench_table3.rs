//! Paper Table 3: SPA-Cache × parallel decoding (Fast-dLLM threshold
//! unmasking).  Compares baseline / Fast-dLLM-parallel / ours+parallel /
//! ours+fused-multistep across the task suites.

use spa_cache::bench::runner::{eval_method, sample_count, task_samples};
use spa_cache::bench::{fmt_acc, fmt_tps, Table};
use spa_cache::coordinator::decode::UnmaskMode;
use spa_cache::coordinator::cache::{IndexPolicy, MethodSpec};
use spa_cache::model::tasks::ALL_TASKS;
use spa_cache::runtime::engine::Engine;
use spa_cache::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let n = args.usize_or("samples", sample_count(!args.flag("full")));
    let seed = args.u64_or("seed", 42);
    let model = args.str_or("model", "llada_s");
    let thr = args.f64_or("threshold", 0.9);

    let mut table = Table::new(
        &format!("Table 3 — parallel decoding integration, {model} (threshold {thr})"),
        &["task", "method", "TPS", "accuracy", "agreement"],
    );
    for task in ALL_TASKS {
        let samples = task_samples(&engine, task, n, seed);
        let par = UnmaskMode::Parallel { threshold: thr };
        let cases: Vec<(&str, MethodSpec, UnmaskMode)> = vec![
            ("baseline", MethodSpec::Vanilla, UnmaskMode::Sequential),
            (
                "+ Fast-dLLM",
                MethodSpec::Manual {
                    k: task.block_len().min(32),
                    policy: IndexPolicy::Block,
                    refresh_interval: 0,
                },
                UnmaskMode::BlockParallel { threshold: thr },
            ),
            (
                "+ Ours (parallel)",
                MethodSpec::Spa { variant: "spa_default".into(), refresh_interval: 0 },
                par,
            ),
            ("+ Ours (fused msteps)", MethodSpec::Multistep, par),
        ];
        let mut baseline_tps = 0.0;
        let mut reference = None;
        for (name, spec, mode) in cases {
            if name.contains("fused") && model != "llada_s" {
                continue; // multistep variant is built for llada_s only
            }
            let r = eval_method(&engine, &model, spec, mode, &samples, reference.as_ref())?;
            if name == "baseline" {
                baseline_tps = r.tps;
            }
            table.row(vec![
                task.name().into(),
                name.into(),
                fmt_tps(r.tps, baseline_tps),
                fmt_acc(r.accuracy, r.n),
                format!("{:.3}", r.agreement),
            ]);
            if name == "baseline" {
                reference = Some(r);
            }
        }
    }
    table.print();
    table.append_to("bench_results.txt");
    Ok(())
}
