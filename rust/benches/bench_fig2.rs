//! Paper Figure 2 (+ Figure 6 via --model, Table 6 fit): distribution of
//! drifting tokens across layers, the fitted Eq. 5 dynamic threshold, and
//! the uniform threshold it replaces.

use spa_cache::analysis::drift::run_probe;
use spa_cache::bench::Table;
use spa_cache::coordinator::group::pack_group;
use spa_cache::model::schedule::fit_piecewise_gaussian;
use spa_cache::model::tasks::{make_sample, ALL_TASKS};
use spa_cache::model::tokenizer::Tokenizer;
use spa_cache::runtime::engine::Engine;
use spa_cache::util::cli::Args;
use spa_cache::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let steps = args.usize_or("steps", 16);
    let models: Vec<String> = args
        .str_or("models", "llada_s,dream_s,llada15_s")
        .split(',')
        .map(String::from)
        .collect();

    let mut fit_table = Table::new(
        "Table 6 — fitted piecewise-Gaussian hyperparameters",
        &["model", "l_p", "rho_p", "rho_1", "rho_L", "python-fit l_p/rho_p"],
    );

    for model in &models {
        let tok = Tokenizer::from_manifest(&engine.manifest.charset);
        let mut rng = Rng::new(args.u64_or("seed", 7));
        let (b, n) = (engine.manifest.batch, engine.manifest.seq_len);
        let samples: Vec<_> = (0..b)
            .map(|i| make_sample(ALL_TASKS[i % ALL_TASKS.len()], &mut rng, &tok, n))
            .collect();
        let (mut tokens, mut slots) = pack_group(&samples, b, n, 16);
        let profile = run_probe(&engine, model, &mut tokens, &mut slots, steps, 0.6)?;
        let drift = profile.mean_drift();
        let fit = fit_piecewise_gaussian(&drift, 0.5);

        let mut table = Table::new(
            &format!("Figure 2/6 — drift fraction across layers, {model} (tau=0.95)"),
            &["layer", "drift frac", "fitted rho(l)", "uniform rho_p", "bar"],
        );
        let nl = drift.len();
        for (i, &d) in drift.iter().enumerate() {
            let bar: String =
                std::iter::repeat('#').take((d * 200.0).round() as usize).collect();
            table.row(vec![
                format!("{}", i + 1),
                format!("{:.4}", d),
                format!("{:.4}", fit.rho(i + 1, nl)),
                format!("{:.4}", fit.rho_p),
                bar,
            ]);
        }
        table.print();
        table.append_to("bench_results.txt");

        // Cross-check against the python build-time fit in the manifest.
        let py = &engine.manifest.model(model)?.fitted_schedule;
        fit_table.row(vec![
            model.clone(),
            format!("{}", fit.l_p),
            format!("{:.3}", fit.rho_p),
            format!("{:.3}", fit.rho_1),
            format!("{:.3}", fit.rho_l),
            format!("{}/{:.3}", py.l_p, py.rho_p),
        ]);
    }
    fit_table.print();
    fit_table.append_to("bench_results.txt");
    Ok(())
}
