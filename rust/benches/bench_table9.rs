//! Paper Table 9: against dKV-Cache / Elastic-Cache / d2Cache analogues on
//! GSM8K + MBPP for both models.  (The analogues substitute host-side
//! confidence/locality signals for attention-weight statistics — see
//! DESIGN.md §2 and coordinator::cache.)

use spa_cache::bench::runner::{eval_method, sample_count, task_samples};
use spa_cache::bench::{fmt_acc, fmt_tps, Table};
use spa_cache::coordinator::decode::UnmaskMode;
use spa_cache::coordinator::cache::{IndexPolicy, MethodSpec};
use spa_cache::model::tasks::Task;
use spa_cache::runtime::engine::Engine;
use spa_cache::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let n = args.usize_or("samples", sample_count(!args.flag("full")));
    let seed = args.u64_or("seed", 42);
    let models: Vec<String> =
        args.str_or("models", "llada_s,dream_s").split(',').map(String::from).collect();

    let mut table = Table::new(
        "Table 9 — vs dKV-Cache / Elastic-Cache / d2Cache analogues",
        &["model", "task", "method", "TPS", "TTFT(ms)", "accuracy", "agreement"],
    );
    for model in &models {
        for task in [Task::Gsm8kS, Task::MbppS] {
            let samples = task_samples(&engine, task, n, seed);
            let k = task.block_len().min(32).max(16);
            let seq = UnmaskMode::Sequential;
            let cases: Vec<(&str, MethodSpec)> = vec![
                ("vanilla", MethodSpec::Vanilla),
                ("dKV-Cache", MethodSpec::Manual { k, policy: IndexPolicy::Window, refresh_interval: 16 }),
                ("Elastic-Cache", MethodSpec::Manual { k, policy: IndexPolicy::Window, refresh_interval: 8 }),
                ("d2Cache", MethodSpec::Manual { k, policy: IndexPolicy::LowConfidence, refresh_interval: 16 }),
                ("Ours", MethodSpec::Spa { variant: "spa_default".into(), refresh_interval: 0 }),
            ];
            let mut baseline_tps = 0.0;
            let mut reference = None;
            for (name, spec) in cases {
                let r = eval_method(&engine, model, spec, seq, &samples, reference.as_ref())?;
                if name == "vanilla" {
                    baseline_tps = r.tps;
                }
                table.row(vec![
                    model.clone(),
                    task.name().into(),
                    name.into(),
                    fmt_tps(r.tps, baseline_tps),
                    format!("{:.1}", r.ttft_ms),
                    fmt_acc(r.accuracy, r.n),
                    format!("{:.3}", r.agreement),
                ]);
                if name == "vanilla" {
                    reference = Some(r);
                }
            }
        }
    }
    table.print();
    table.append_to("bench_results.txt");
    Ok(())
}
