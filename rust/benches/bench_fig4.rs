//! Paper Figure 4: component-wise latency decomposition.
//!
//! The paper profiles one layer's phases on GPU; here we decompose the
//! end-to-end step cost across executables that isolate each component:
//!   full attention+FFN  = vanilla step
//!   sparse attn+FFN only = manual step at k (no identification)
//!   + full-d identification = spa_value_u25 step
//!   + singular identification = spa_singular{r}_u25 step
//! The deltas between them estimate the identification overhead that the
//! singular proxy removes — the paper's Fig. 4 story.

use spa_cache::bench::{time_ms, Table};
use spa_cache::coordinator::request::SlotState;
use spa_cache::model::tasks::{make_sample, Task};
use spa_cache::model::tokenizer::Tokenizer;
use spa_cache::runtime::engine::Engine;
use spa_cache::runtime::tensor::{literal_i32, literal_zeros_f32};
use spa_cache::util::cli::Args;
use spa_cache::util::rng::Rng;
use xla::Literal;

fn step_cost(engine: &Engine, variant: &str, tokens: &[i32], iters: usize) -> anyhow::Result<f64> {
    let v = engine.load_variant(variant)?;
    let (b, n) = (v.info.batch, v.info.seq_len);
    let tok_lit = literal_i32(&[b, n], tokens)?;
    // Build caches by refreshing when the variant needs them.
    let mut inputs: Vec<Literal> = Vec::new();
    match v.info.kind.as_str() {
        "vanilla" => {}
        "spa" => {
            let rfr = engine.load_variant(&format!("{variant}_refresh"))?;
            let mut outs = engine.run(&rfr, &[&tok_lit])?;
            inputs = outs.drain(1..).collect();
        }
        "manual" => {
            let k = v.info.manual_k;
            let idx: Vec<i32> = (0..b).flat_map(|_| (0..k as i32)).collect();
            inputs.push(literal_i32(&[b, k], &idx)?);
            for i in v.info.inputs.iter().filter(|i| i.name != "tokens" && i.name != "idx") {
                inputs.push(literal_zeros_f32(&i.shape)?);
            }
        }
        other => anyhow::bail!("unsupported kind {other}"),
    }
    let mut refs: Vec<&Literal> = vec![&tok_lit];
    refs.extend(inputs.iter());
    let s = time_ms(2, iters, || {
        engine.run(&v, &refs).unwrap();
    });
    Ok(s.mean)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let model = args.str_or("model", "llada_s");
    let iters = args.usize_or("iters", 10);

    let tok = Tokenizer::from_manifest(&engine.manifest.charset);
    let mut rng = Rng::new(args.u64_or("seed", 7));
    let (b, n) = (engine.manifest.batch, engine.manifest.seq_len);
    let tokens: Vec<i32> = (0..b)
        .flat_map(|_| make_sample(Task::Gsm8kS, &mut rng, &tok, n).tokens)
        .collect();
    let _ = SlotState::empty();

    let full = step_cost(&engine, &format!("{model}__vanilla"), &tokens, iters)?;
    let sparse_only = step_cost(&engine, &format!("{model}__manual_k32"), &tokens, iters)?;
    let value_id = step_cost(&engine, &format!("{model}__spa_value_u25"), &tokens, iters)?;
    let singular_id =
        step_cost(&engine, &format!("{model}__spa_singular16_u25"), &tokens, iters)?;

    let mut table = Table::new(
        &format!("Figure 4 — component-wise step latency, {model} (k=32 of N={n})"),
        &["configuration", "step ms", "identification ms", "vs vanilla"],
    );
    let id_value = (value_id - sparse_only).max(0.0);
    let id_sing = (singular_id - sparse_only).max(0.0);
    table.row(vec!["vanilla (full attn+FFN)".into(), format!("{full:.2}"), "-".into(), "1.00x".into()]);
    table.row(vec![
        "sparse attn+FFN (no ident.)".into(),
        format!("{sparse_only:.2}"),
        "0.00".into(),
        format!("{:.2}x", full / sparse_only),
    ]);
    table.row(vec![
        "+ value identification (full d)".into(),
        format!("{value_id:.2}"),
        format!("{id_value:.2}"),
        format!("{:.2}x", full / value_id),
    ]);
    table.row(vec![
        "+ singular identification (r=16)".into(),
        format!("{singular_id:.2}"),
        format!("{id_sing:.2}"),
        format!("{:.2}x", full / singular_id),
    ]);
    table.print();
    table.append_to("bench_results.txt");
    println!(
        "identification overhead: value {:.2} ms -> singular {:.2} ms ({:.1}% saved)",
        id_value,
        id_sing,
        if id_value > 0.0 { 100.0 * (1.0 - id_sing / id_value) } else { 0.0 }
    );
    Ok(())
}
