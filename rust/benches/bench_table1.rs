//! Paper Table 1: identifier-type comparison on GSM8K (LLaDA-s).
//! Query/Key/Value/attn-input/attn-output/singular identifiers at a uniform
//! ρ=0.25 budget versus the no-cache baseline.

use spa_cache::bench::runner::{eval_method, sample_count, task_samples};
use spa_cache::bench::{fmt_acc, fmt_tps, Table};
use spa_cache::coordinator::decode::UnmaskMode;
use spa_cache::coordinator::cache::MethodSpec;
use spa_cache::model::tasks::Task;
use spa_cache::runtime::engine::Engine;
use spa_cache::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let n = args.usize_or("samples", sample_count(!args.flag("full")));
    let samples = task_samples(&engine, Task::Gsm8kS, n, args.u64_or("seed", 42));
    let model = args.str_or("model", "llada_s");

    let rows: Vec<(&str, Option<&str>)> = vec![
        ("baseline (none)", None),
        ("query", Some("spa_query_u25")),
        ("key", Some("spa_key_u25")),
        ("value", Some("spa_value_u25")),
        ("attn. input", Some("spa_attnin_u25")),
        ("attn. output", Some("spa_attnout_u25")),
        ("singular (ours)", Some("spa_singular16_u25")),
    ];

    let mut table = Table::new(
        &format!("Table 1 — identifier comparison, {model}, gsm8k_s, uniform rho=0.25"),
        &["identifier", "TPS", "TTFT(ms)", "accuracy", "agreement"],
    );
    let mut baseline_tps = 0.0;
    let mut reference = None;
    for (name, variant) in rows {
        let spec = match variant {
            None => MethodSpec::Vanilla,
            Some(v) => MethodSpec::Spa { variant: v.into(), refresh_interval: 0 },
        };
        let r = eval_method(
            &engine, &model, spec, UnmaskMode::Sequential, &samples, reference.as_ref(),
        )?;
        if variant.is_none() {
            baseline_tps = r.tps;
        }
        table.row(vec![
            name.into(),
            fmt_tps(r.tps, baseline_tps),
            format!("{:.1}", r.ttft_ms),
            fmt_acc(r.accuracy, r.n),
            format!("{:.3}", r.agreement),
        ]);
        if variant.is_none() {
            reference = Some(r);
        }
    }
    table.print();
    table.append_to("bench_results.txt");
    Ok(())
}
