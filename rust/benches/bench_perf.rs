//! §Perf: per-step latency decomposition of the serving hot path.
//! Measures executable dispatch cost, host<->device traffic and compute for
//! the main variants; drives the optimization log in EXPERIMENTS.md §Perf.

use std::time::Instant;

use spa_cache::bench::{time_ms, Table};
use spa_cache::model::tasks::{make_sample, Task};
use spa_cache::model::tokenizer::Tokenizer;
use spa_cache::runtime::engine::Engine;
use spa_cache::runtime::tensor::{literal_i32, to_f32_vec};
use spa_cache::util::cli::Args;
use spa_cache::util::rng::Rng;
use xla::Literal;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let model = args.str_or("model", "llada_s");
    let iters = args.usize_or("iters", 15);

    let tok = Tokenizer::from_manifest(&engine.manifest.charset);
    let mut rng = Rng::new(7);
    let (b, n) = (engine.manifest.batch, engine.manifest.seq_len);
    let tokens: Vec<i32> =
        (0..b).flat_map(|_| make_sample(Task::Gsm8kS, &mut rng, &tok, n).tokens).collect();
    let tok_lit = literal_i32(&[b, n], &tokens)?;

    let mut table = Table::new(
        &format!("Perf — step latency breakdown, {model}, B={b} N={n}"),
        &["variant", "mean ms", "p50", "p90", "tokens/s @1tok/step"],
    );

    // vanilla
    let van = engine.load_variant(&format!("{model}__vanilla"))?;
    let s = time_ms(3, iters, || {
        engine.run(&van, &[&tok_lit]).unwrap();
    });
    table.row(vec![
        "vanilla".into(),
        format!("{:.2}", s.mean),
        format!("{:.2}", s.p50),
        format!("{:.2}", s.p90),
        format!("{:.1}", b as f64 * 1e3 / s.mean),
    ]);

    // spa default (step, after refresh)
    for variant in [
        format!("{model}__spa_default"),
        format!("{model}__spa_value_u25"),
        format!("{model}__spa_singular16_u25"),
        format!("{model}__manual_k16"),
        format!("{model}__multistep_default"),
    ] {
        if !engine.manifest.variants.contains_key(&variant) {
            continue;
        }
        let v = engine.load_variant(&variant)?;
        let mut inputs: Vec<Literal> = Vec::new();
        match v.info.kind.as_str() {
            "spa" | "multistep" => {
                let rname = if v.info.kind == "multistep" {
                    format!("{model}__spa_default_refresh")
                } else {
                    format!("{variant}_refresh")
                };
                let rfr = engine.load_variant(&rname)?;
                let mut outs = engine.run(&rfr, &[&tok_lit])?;
                inputs = outs.drain(1..).collect();
            }
            "manual" => {
                let k = v.info.manual_k;
                let idx: Vec<i32> = (0..b).flat_map(|_| (0..k as i32)).collect();
                inputs.push(literal_i32(&[b, k], &idx)?);
                let rfr = engine.load_variant(&format!("{model}__manual_full"))?;
                let full_k = rfr.info.manual_k;
                let fidx: Vec<i32> = (0..b).flat_map(|_| (0..full_k as i32)).collect();
                let fidx_lit = literal_i32(&[b, full_k], &fidx)?;
                let zeros: Vec<Literal> = rfr
                    .info
                    .inputs
                    .iter()
                    .filter(|i| i.name != "tokens" && i.name != "idx")
                    .map(|i| spa_cache::runtime::tensor::literal_zeros_f32(&i.shape))
                    .collect::<anyhow::Result<_>>()?;
                let mut refs: Vec<&Literal> = vec![&tok_lit, &fidx_lit];
                refs.extend(zeros.iter());
                let mut outs = engine.run(&rfr, &refs)?;
                inputs.extend(outs.drain(1..));
            }
            _ => {}
        }
        let mut refs: Vec<&Literal> = vec![&tok_lit];
        refs.extend(inputs.iter());
        let s = time_ms(3, iters, || {
            engine.run(&v, &refs).unwrap();
        });
        let toks_per_step = if v.info.kind == "multistep" { v.info.msteps } else { 1 };
        table.row(vec![
            variant.clone(),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.p50),
            format!("{:.2}", s.p90),
            format!("{:.1}", (b * toks_per_step) as f64 * 1e3 / s.mean),
        ]);
    }

    // Host-copy cost accounting: logits + cache literal readback.
    let spa = engine.load_variant(&format!("{model}__spa_default"))?;
    let rfr = engine.load_variant(&format!("{model}__spa_default_refresh"))?;
    let outs = engine.run(&rfr, &[&tok_lit])?;
    let t0 = Instant::now();
    let mut bytes = 0usize;
    for o in &outs {
        bytes += to_f32_vec(o).map(|v| v.len() * 4).unwrap_or(0);
    }
    let copy_ms = t0.elapsed().as_secs_f64() * 1e3;
    let _ = spa;
    table.print();
    table.append_to("bench_results.txt");
    println!(
        "cache+logits host readback: {:.1} MiB in {:.2} ms ({:.1} GB/s)",
        bytes as f64 / 1048576.0,
        copy_ms,
        bytes as f64 / 1e6 / copy_ms
    );
    let st = engine.stats();
    println!(
        "engine totals: {} executions, mean {:.2} ms",
        st.executions,
        st.exec_ms_total / st.executions.max(1) as f64
    );
    Ok(())
}
