//! Paper Table 5: impact of the singular-proxy rank r (paper sweeps
//! 32..512 against d=4096; we sweep 2..64 against d=128 — same ratios).
//! Also prints the Theorem 3.4 bound proxy (per-layer mean 2(λ_{r+1}/λ_r)²
//! is reported by the python side; here we show TPS/accuracy trade-off).

use spa_cache::bench::runner::{eval_method, sample_count, task_samples};
use spa_cache::bench::{fmt_acc, fmt_tps, Table};
use spa_cache::coordinator::decode::UnmaskMode;
use spa_cache::coordinator::cache::MethodSpec;
use spa_cache::model::tasks::Task;
use spa_cache::runtime::engine::Engine;
use spa_cache::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let engine = Engine::from_default_artifacts()?;
    let n = args.usize_or("samples", sample_count(!args.flag("full")));
    let samples = task_samples(&engine, Task::Gsm8kS, n, args.u64_or("seed", 42));
    let model = args.str_or("model", "llada_s");

    let mut rows: Vec<(String, Option<String>)> =
        vec![("none (baseline)".into(), None), ("value (full d)".into(), Some("spa_value_u25".into()))];
    for r in [64, 32, 16, 8, 4, 2] {
        rows.push((format!("singular r={r}"), Some(format!("spa_singular{r}_u25"))));
    }

    let mut table = Table::new(
        &format!("Table 5 — proxy rank sweep, {model}, gsm8k_s, uniform rho=0.25"),
        &["identifier", "TPS", "accuracy", "agreement"],
    );
    let mut baseline_tps = 0.0;
    let mut reference = None;
    for (name, variant) in rows {
        let spec = match &variant {
            None => MethodSpec::Vanilla,
            Some(v) => MethodSpec::Spa { variant: v.clone(), refresh_interval: 0 },
        };
        let r = eval_method(
            &engine, &model, spec, UnmaskMode::Sequential, &samples, reference.as_ref(),
        )?;
        if variant.is_none() {
            baseline_tps = r.tps;
        }
        table.row(vec![
            name,
            fmt_tps(r.tps, baseline_tps),
            fmt_acc(r.accuracy, r.n),
            format!("{:.3}", r.agreement),
        ]);
        if variant.is_none() {
            reference = Some(r);
        }
    }
    table.print();
    table.append_to("bench_results.txt");
    Ok(())
}
